//! Wire encodings.
//!
//! * [`FileRequest`]/[`FileResponse`] — the host↔DPU ring records of
//!   Fig 9: a request header with the write payload *inlined* (so one
//!   DMA-read moves the whole request), and a response header with the
//!   read payload inlined.
//! * [`NetMsg`]/[`NetResp`] — the client↔server application protocol of
//!   the evaluation app (§8.1): length-prefixed frames, each carrying a
//!   batch of requests (batching is how the client controls load).
//!
//! Everything is hand-rolled little-endian — the hot path never touches
//! a serde-style framework.

pub mod wire;

use crate::buf::{BufView, ByteRope};
use wire::{Reader, ViewReader, Writer};

/// File operation kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileOpKind {
    Read = 0,
    Write = 1,
}

/// Request record on the request ring (Fig 9 top).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileRequest {
    pub req_id: u64,
    pub file_id: u32,
    pub kind: FileOpKind,
    pub offset: u64,
    /// Read size (reads) — writes carry `data.len()` implicitly.
    pub size: u32,
    /// Inlined write payload (empty for reads). A refcounted view: the
    /// DPU intake path aliases the DMA'd request batch instead of
    /// copying each record's payload out of it.
    pub data: BufView,
}

impl FileRequest {
    pub fn read(req_id: u64, file_id: u32, offset: u64, size: u32) -> Self {
        FileRequest {
            req_id,
            file_id,
            kind: FileOpKind::Read,
            offset,
            size,
            data: BufView::empty(),
        }
    }

    pub fn write(req_id: u64, file_id: u32, offset: u64, data: Vec<u8>) -> Self {
        Self::write_view(req_id, file_id, offset, BufView::from_vec(data))
    }

    /// Write request whose payload references existing buffer storage.
    pub fn write_view(req_id: u64, file_id: u32, offset: u64, data: BufView) -> Self {
        FileRequest {
            req_id,
            file_id,
            kind: FileOpKind::Write,
            offset,
            size: data.len() as u32,
            data,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(29 + self.data.len());
        w.u64(self.req_id);
        w.u32(self.file_id);
        w.u8(self.kind as u8);
        w.u64(self.offset);
        w.u32(self.size);
        w.u32(self.data.len() as u32);
        w.bytes(&self.data);
        w.into_vec()
    }

    /// Owned-copy decode (host-local paths, tests): stages `buf` and
    /// delegates to [`Self::decode_view`] — one parser, one layout.
    pub fn decode(buf: &[u8]) -> Option<Self> {
        // LINT: copy-ok(owned-copy decode is the host-local/test
        // convenience; the zero-copy parser is decode_view below)
        Self::decode_view(&BufView::from_vec(buf.to_vec()))
    }

    /// THE request parser. Zero-copy: the write payload comes back as a
    /// refcounted sub-view of `view` (Fig 9: the record the DMA moved
    /// IS the buffer the SSD driver consumes — no per-record copy on
    /// the DPU).
    pub fn decode_view(view: &BufView) -> Option<Self> {
        let mut r = ViewReader::new(view.clone());
        let req_id = r.u64()?;
        let file_id = r.u32()?;
        let kind = match r.u8()? {
            0 => FileOpKind::Read,
            1 => FileOpKind::Write,
            _ => return None,
        };
        let offset = r.u64()?;
        let size = r.u32()?;
        let dlen = r.u32()? as usize;
        let data = r.take_view(dlen)?;
        Some(FileRequest { req_id, file_id, kind, offset, size, data })
    }

    /// Size of the expected response record — what the DPU file service
    /// uses to pre-allocate response space before submitting the I/O
    /// (§4.3: "for read requests we use the requested size as the read
    /// data size").
    pub fn expected_response_len(&self) -> usize {
        match self.kind {
            FileOpKind::Read => FileResponse::HEADER_LEN + self.size as usize,
            FileOpKind::Write => FileResponse::HEADER_LEN,
        }
    }
}

/// Completion status codes on the response ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// §4.3: pre-allocated responses start as *pending*.
    Pending = 0,
    Ok = 1,
    Error = 2,
}

/// Response record on the response ring (Fig 9 bottom).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileResponse {
    pub req_id: u64,
    pub status: Status,
    /// Inlined read payload (empty for writes).
    pub data: Vec<u8>,
}

impl FileResponse {
    pub const HEADER_LEN: usize = 8 + 1 + 4;

    /// Encode only the fixed header; the payload follows as a separate
    /// part (for vectored ring pushes — the DPU DMA-writes header and
    /// pre-allocated read buffer without ever concatenating them).
    pub fn encode_header(req_id: u64, status: Status, payload_len: usize) -> [u8; Self::HEADER_LEN] {
        let mut h = [0u8; Self::HEADER_LEN];
        h[..8].copy_from_slice(&req_id.to_le_bytes());
        h[8] = status as u8;
        h[9..13].copy_from_slice(&(payload_len as u32).to_le_bytes());
        h
    }

    /// Contiguous encoding: header (via the same [`Self::encode_header`]
    /// the vectored delivery path uses — one layout) + payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(Self::HEADER_LEN + self.data.len());
        // LINT: copy-ok(contiguous owned encode for host-local paths; the
        // DPU delivery path is vectored — encode_header + payload view)
        v.extend_from_slice(&Self::encode_header(self.req_id, self.status, self.data.len()));
        v.extend_from_slice(&self.data);
        v
    }

    pub fn decode(buf: &[u8]) -> Option<Self> {
        let mut r = Reader::new(buf);
        let req_id = r.u64()?;
        let status = match r.u8()? {
            0 => Status::Pending,
            1 => Status::Ok,
            2 => Status::Error,
            _ => return None,
        };
        let dlen = r.u32()? as usize;
        // LINT: copy-ok(owned decode at the host API boundary; the payload
        // leaves the ring here by design)
        let data = r.take(dlen)?.to_vec();
        Some(FileResponse { req_id, status, data })
    }

    /// Salvage the request id from a record whose full decode failed:
    /// the id is the first — fixed — header field, so it survives a
    /// corrupt status byte or truncated payload. Lets the host library
    /// fail the matching pending operation instead of leaking it (a
    /// leaked entry wedges `in_flight()`-based quiesce loops forever).
    ///
    /// Best-effort by construction: the record carries no checksum
    /// (the layout is golden-pinned), so corruption INSIDE the id
    /// bytes cannot be detected and may attribute the failure to a
    /// different outstanding op. Only records that still carry the
    /// complete fixed header are salvaged — anything shorter is too
    /// damaged to trust — and the consumer keeps the misattribution
    /// observable: the guessed-at op's genuine completion later counts
    /// as an orphan, and every salvage increments `bad_records`.
    pub fn peek_req_id(buf: &[u8]) -> Option<u64> {
        if buf.len() < Self::HEADER_LEN {
            return None;
        }
        Some(u64::from_le_bytes(buf.get(..8)?.try_into().ok()?))
    }
}

/// One application-level request inside a network message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AppRequest {
    /// Raw remote file read (the §8.1 benchmark app).
    Read { file_id: u32, offset: u64, size: u32 },
    /// Raw remote file write.
    Write { file_id: u32, offset: u64, data: Vec<u8> },
    /// Hyperscale-style GetPage@LSN (§9.1).
    GetPage { page_id: u64, lsn: u64 },
    /// FASTER-style point read (§9.2).
    KvGet { key: u64 },
    /// FASTER-style upsert / read-modify-write (host-only).
    KvUpsert { key: u64, value: Vec<u8> },
}

impl AppRequest {
    /// True when this request kind is even a candidate for DPU
    /// offloading (writes/updates never are, §3).
    pub fn is_read(&self) -> bool {
        matches!(self, AppRequest::Read { .. } | AppRequest::GetPage { .. } | AppRequest::KvGet { .. })
    }

    fn encode_into(&self, w: &mut Writer) {
        match self {
            AppRequest::Read { file_id, offset, size } => {
                w.u8(0);
                w.u32(*file_id);
                w.u64(*offset);
                w.u32(*size);
            }
            AppRequest::Write { file_id, offset, data } => {
                w.u8(1);
                w.u32(*file_id);
                w.u64(*offset);
                w.u32(data.len() as u32);
                w.bytes(data);
            }
            AppRequest::GetPage { page_id, lsn } => {
                w.u8(2);
                w.u64(*page_id);
                w.u64(*lsn);
            }
            AppRequest::KvGet { key } => {
                w.u8(3);
                w.u64(*key);
            }
            AppRequest::KvUpsert { key, value } => {
                w.u8(4);
                w.u64(*key);
                w.u32(value.len() as u32);
                w.bytes(value);
            }
        }
    }

    fn decode_from(r: &mut Reader) -> Option<Self> {
        Some(match r.u8()? {
            0 => AppRequest::Read { file_id: r.u32()?, offset: r.u64()?, size: r.u32()? },
            1 => {
                let file_id = r.u32()?;
                let offset = r.u64()?;
                let n = r.u32()? as usize;
                // LINT: copy-ok(owned decode into the AppRequest value; the
                // request payload leaves the stream buffer here by design)
                AppRequest::Write { file_id, offset, data: r.take(n)?.to_vec() }
            }
            2 => AppRequest::GetPage { page_id: r.u64()?, lsn: r.u64()? },
            3 => AppRequest::KvGet { key: r.u64()? },
            4 => {
                let key = r.u64()?;
                let n = r.u32()? as usize;
                // LINT: copy-ok(owned decode, as for Write above)
                AppRequest::KvUpsert { key, value: r.take(n)?.to_vec() }
            }
            _ => return None,
        })
    }
}

/// A client→server message: a batch of requests (§8.1: "the number of
/// requests batched in a message").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetMsg {
    pub msg_id: u64,
    pub requests: Vec<AppRequest>,
}

impl NetMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(16);
        w.u64(self.msg_id);
        w.u16(self.requests.len() as u16);
        for req in &self.requests {
            req.encode_into(&mut w);
        }
        w.into_vec()
    }

    pub fn decode(buf: &[u8]) -> Option<Self> {
        let mut r = Reader::new(buf);
        let msg_id = r.u64()?;
        let n = r.u16()? as usize;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            requests.push(AppRequest::decode_from(&mut r)?);
        }
        Some(NetMsg { msg_id, requests })
    }
}

/// A server→client per-request response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetResp {
    pub msg_id: u64,
    /// Index of the request within its message.
    pub idx: u16,
    pub status: u8,
    /// Response payload as a refcounted view — for offloaded reads this
    /// is the pooled buffer the SSD DMA'd into (Fig 12 ③), referenced
    /// all the way onto the wire, never duplicated.
    pub payload: BufView,
}

impl NetResp {
    pub const OK: u8 = 0;
    pub const ERR: u8 = 1;
    /// Fixed header bytes preceding the payload.
    pub const HEADER_LEN: usize = 8 + 2 + 1 + 4;
    /// Length-prefixed frame header: `u32` frame length + header.
    pub const FRAME_HEADER_LEN: usize = 4 + Self::HEADER_LEN;

    /// The single definition of this response's on-wire frame header
    /// (`u32 frame-len | msg_id | idx | status | payload-len`) — shared
    /// by every framing path so the layout can never diverge.
    pub fn frame_header(&self) -> [u8; Self::FRAME_HEADER_LEN] {
        let mut h = [0u8; Self::FRAME_HEADER_LEN];
        h[..4].copy_from_slice(&((Self::HEADER_LEN + self.payload.len()) as u32).to_le_bytes());
        h[4..12].copy_from_slice(&self.msg_id.to_le_bytes());
        h[12..14].copy_from_slice(&self.idx.to_le_bytes());
        h[14] = self.status;
        h[15..19].copy_from_slice(&(self.payload.len() as u32).to_le_bytes());
        h
    }

    /// Contiguous encoding: the [`Self::frame_header`] layout minus its
    /// `u32` frame-length prefix, then the payload — one layout shared
    /// with every framing path.
    pub fn encode(&self) -> Vec<u8> {
        let h = self.frame_header();
        let mut v = Vec::with_capacity(Self::HEADER_LEN + self.payload.len());
        // LINT: copy-ok(contiguous owned encode for host-local/test paths;
        // the wire path is frame_into_rope, which never copies the payload)
        v.extend_from_slice(&h[4..]);
        v.extend_from_slice(&self.payload);
        v
    }

    /// Append this response as one length-prefixed frame to `rope`
    /// without copying the payload — byte-identical to
    /// `framing::write_frame(out, &self.encode())`.
    pub fn frame_into_rope(self, rope: &mut ByteRope) {
        // LINT: copy-ok(19-byte fixed header materialized once; the payload
        // itself rides as a refcounted view)
        rope.push(BufView::from_vec(self.frame_header().to_vec()));
        rope.push(self.payload);
    }

    pub fn decode(buf: &[u8]) -> Option<Self> {
        let mut r = Reader::new(buf);
        let msg_id = r.u64()?;
        let idx = r.u16()?;
        let status = r.u8()?;
        let n = r.u32()? as usize;
        Some(NetResp {
            msg_id,
            idx,
            status,
            // LINT: copy-ok(owned decode at the client API boundary)
            payload: BufView::from_vec(r.take(n)?.to_vec()),
        })
    }
}

/// Length-prefixed framing over a byte stream: `u32 len | frame`.
pub mod framing {
    /// Append one frame to `out`.
    pub fn write_frame(out: &mut Vec<u8>, frame: &[u8]) {
        // LINT: copy-ok(owned framing helper for host-local/test paths; the
        // zero-copy send path frames via NetResp::frame_into_rope)
        out.extend_from_slice(&(frame.len() as u32).to_le_bytes());
        out.extend_from_slice(frame);
    }

    /// Try to split one frame off the front of `buf`; returns the frame
    /// and consumes it from `buf`.
    pub fn read_frame(buf: &mut Vec<u8>) -> Option<Vec<u8>> {
        if buf.len() < 4 {
            return None;
        }
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        if buf.len() < 4 + len {
            return None;
        }
        // LINT: copy-ok(owned framing helper; see write_frame)
        let frame = buf[4..4 + len].to_vec();
        buf.drain(..4 + len);
        Some(frame)
    }

    /// Reassembly buffer with offset-based consumption: consuming a
    /// frame advances a cursor instead of memmoving the remainder
    /// (perf pass L3-6); the buffer compacts lazily.
    #[derive(Debug, Default)]
    pub struct StreamBuf {
        buf: Vec<u8>,
        pos: usize,
    }

    impl StreamBuf {
        pub fn new() -> Self {
            Self::default()
        }

        pub fn extend(&mut self, bytes: &[u8]) {
            // LINT: copy-ok(receive-side reassembly ingest from a borrowed
            // socket buffer; the metered path is extend_rope below)
            self.buf.extend_from_slice(bytes);
        }

        /// Absorb a view rope part by part — the receive-side
        /// materialization point. This IS a software copy, so it is
        /// metered on `ledger` (typically the absorbing endpoint's):
        /// the copy-ledger contract is that every memcpy on the data
        /// path is counted exactly once, including this one.
        pub fn extend_rope(&mut self, rope: &crate::buf::ByteRope, ledger: &crate::buf::CopyLedger) {
            if rope.is_empty() {
                return;
            }
            ledger.count_copy(rope.len());
            for part in rope.parts() {
                // LINT: copy-ok(THE metered materialization point — counted
                // on the ledger just above)
                self.buf.extend_from_slice(part.as_slice());
            }
        }

        pub fn len(&self) -> usize {
            self.buf.len() - self.pos
        }

        pub fn is_empty(&self) -> bool {
            self.len() == 0
        }

        /// Pop one complete frame, if present.
        pub fn read_frame(&mut self) -> Option<Vec<u8>> {
            let avail = &self.buf[self.pos..];
            if avail.len() < 4 {
                self.maybe_compact();
                return None;
            }
            let len = u32::from_le_bytes(avail[..4].try_into().unwrap()) as usize;
            if avail.len() < 4 + len {
                self.maybe_compact();
                return None;
            }
            // LINT: copy-ok(frame extraction from the reassembly buffer —
            // the cursor-based StreamBuf already avoids the memmove; the
            // extracted frame must own its bytes past the next extend)
            let frame = avail[4..4 + len].to_vec();
            self.pos += 4 + len;
            self.maybe_compact();
            Some(frame)
        }

        fn maybe_compact(&mut self) {
            if self.pos == self.buf.len() {
                self.buf.clear();
                self.pos = 0;
            } else if self.pos > 4096 && self.pos * 2 > self.buf.len() {
                self.buf.drain(..self.pos);
                self.pos = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_request_roundtrip() {
        let r = FileRequest::read(42, 7, 4096, 1024);
        assert_eq!(FileRequest::decode(&r.encode()), Some(r));
        let w = FileRequest::write(43, 7, 0, vec![1, 2, 3]);
        assert_eq!(FileRequest::decode(&w.encode()), Some(w));
    }

    #[test]
    fn file_response_roundtrip() {
        let resp = FileResponse { req_id: 9, status: Status::Ok, data: vec![5; 100] };
        assert_eq!(FileResponse::decode(&resp.encode()), Some(resp));
    }

    #[test]
    fn expected_response_len_matches_encoding() {
        // The pre-allocation contract: expected_response_len must equal
        // the encoded length of the eventual response.
        let req = FileRequest::read(1, 1, 0, 512);
        let resp = FileResponse { req_id: 1, status: Status::Ok, data: vec![0; 512] };
        assert_eq!(req.expected_response_len(), resp.encode().len());
        let wreq = FileRequest::write(2, 1, 0, vec![0; 100]);
        let wresp = FileResponse { req_id: 2, status: Status::Ok, data: Vec::new() };
        assert_eq!(wreq.expected_response_len(), wresp.encode().len());
    }

    #[test]
    fn net_msg_roundtrip_all_kinds() {
        let m = NetMsg {
            msg_id: 77,
            requests: vec![
                AppRequest::Read { file_id: 1, offset: 8192, size: 1024 },
                AppRequest::Write { file_id: 2, offset: 0, data: vec![9; 64] },
                AppRequest::GetPage { page_id: 12, lsn: 99 },
                AppRequest::KvGet { key: 0xdead },
                AppRequest::KvUpsert { key: 0xbeef, value: vec![1; 8] },
            ],
        };
        assert_eq!(NetMsg::decode(&m.encode()), Some(m));
    }

    #[test]
    fn net_resp_roundtrip() {
        let r = NetResp { msg_id: 5, idx: 3, status: NetResp::OK, payload: vec![7; 9].into() };
        assert_eq!(NetResp::decode(&r.encode()), Some(r));
    }

    #[test]
    fn net_resp_rope_framing_matches_encode() {
        let r = NetResp { msg_id: 9, idx: 1, status: NetResp::OK, payload: vec![3u8; 40].into() };
        let mut classic = Vec::new();
        framing::write_frame(&mut classic, &r.encode());
        let mut rope = crate::buf::ByteRope::new();
        let payload = r.payload.clone();
        r.frame_into_rope(&mut rope);
        assert_eq!(rope.to_vec(), classic);
        // The payload part aliases the original storage — no copy.
        assert!(rope.parts()[1].shares_storage(&payload));
    }

    #[test]
    fn file_request_decode_view_aliases_payload() {
        let req = FileRequest::write(7, 3, 128, vec![0xAB; 300]);
        let enc = BufView::from_vec(req.encode());
        let back = FileRequest::decode_view(&enc).unwrap();
        assert_eq!(back, req);
        assert!(back.data.shares_storage(&enc), "payload is a sub-view of the record");
        // Truncated input still rejected.
        let trunc = enc.slice(0..enc.len() - 1);
        assert_eq!(FileRequest::decode_view(&trunc), None);
    }

    #[test]
    fn framing_handles_partial_input() {
        let mut stream = Vec::new();
        framing::write_frame(&mut stream, b"hello");
        framing::write_frame(&mut stream, b"world");
        // Deliver byte by byte.
        let mut rx = Vec::new();
        let mut frames = Vec::new();
        for b in stream {
            rx.push(b);
            while let Some(f) = framing::read_frame(&mut rx) {
                frames.push(f);
            }
        }
        assert_eq!(frames, vec![b"hello".to_vec(), b"world".to_vec()]);
    }

    #[test]
    fn truncated_decode_is_none() {
        let r = FileRequest::write(1, 2, 3, vec![0; 50]);
        let enc = r.encode();
        assert_eq!(FileRequest::decode(&enc[..enc.len() - 1]), None);
        assert_eq!(NetMsg::decode(&[0u8; 3]), None);
    }

    #[test]
    fn is_read_classification() {
        assert!(AppRequest::Read { file_id: 0, offset: 0, size: 0 }.is_read());
        assert!(AppRequest::GetPage { page_id: 0, lsn: 0 }.is_read());
        assert!(AppRequest::KvGet { key: 0 }.is_read());
        assert!(!AppRequest::Write { file_id: 0, offset: 0, data: vec![] }.is_read());
        assert!(!AppRequest::KvUpsert { key: 0, value: vec![] }.is_read());
    }
}
