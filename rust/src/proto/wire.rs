//! Minimal little-endian byte writer/reader shared by the wire formats.
//!
//! Two families:
//!
//! * [`Writer`]/[`Reader`] — plain owned-`Vec`/borrowed-slice codecs
//!   (control plane, host-local paths, tests).
//! * [`PooledWriter`]/[`ViewReader`] — the zero-copy counterparts:
//!   the writer encodes into a borrowed [`crate::buf::BufPool`] slot
//!   (no heap allocation in steady state) and the reader parses a
//!   [`crate::buf::BufView`], yielding payload fields as refcounted
//!   sub-views instead of copied vectors.

use crate::buf::{BufPool, BufView, PooledBuf};

/// Append-only byte writer.
#[derive(Debug, Default)]
pub struct Writer(Vec<u8>);

impl Writer {
    pub fn new() -> Self {
        Writer(Vec::new())
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer(Vec::with_capacity(n))
    }

    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    #[inline]
    pub fn u16(&mut self, v: u16) {
        // LINT: copy-ok(fixed-width header field serialization)
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        // LINT: copy-ok(fixed-width header field serialization)
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        // LINT: copy-ok(fixed-width header field serialization)
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn bytes(&mut self, b: &[u8]) {
        // LINT: copy-ok(owned-Vec Writer IS the copying codec family; the
        // zero-copy encode path is PooledWriter — see module doc)
        self.0.extend_from_slice(b);
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.0
    }
}

/// Bounds-checked byte reader; every accessor returns `None` past the
/// end instead of panicking (wire data is untrusted).
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, at: 0 }
    }

    #[inline]
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.at + n > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Some(s)
    }

    #[inline]
    pub fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    #[inline]
    pub fn u16(&mut self) -> Option<u16> {
        self.take(2).map(|b| u16::from_le_bytes(b.try_into().unwrap()))
    }

    #[inline]
    pub fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    }

    #[inline]
    pub fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }
}

/// Fixed-capacity writer over a pooled buffer: encodes wire records
/// directly into pre-allocated DMA-able memory, so steady-state message
/// construction performs zero heap allocations. Capacity must be sized
/// by the caller (wire records have computable lengths); overflowing is
/// a programming error and panics.
pub struct PooledWriter {
    buf: PooledBuf,
    at: usize,
}

impl PooledWriter {
    pub fn new(pool: &BufPool, capacity: usize) -> Self {
        PooledWriter { buf: pool.allocate(capacity), at: 0 }
    }

    #[inline]
    fn put(&mut self, b: &[u8]) {
        let end = self.at + b.len();
        assert!(end <= self.buf.len(), "PooledWriter overflow: {end} > {}", self.buf.len());
        self.buf.as_mut_slice()[self.at..end].copy_from_slice(b);
        self.at = end;
    }

    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.put(&[v]);
    }

    #[inline]
    pub fn u16(&mut self, v: u16) {
        self.put(&v.to_le_bytes());
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.put(&v.to_le_bytes());
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.put(&v.to_le_bytes());
    }

    #[inline]
    pub fn bytes(&mut self, b: &[u8]) {
        self.put(b);
    }

    pub fn len(&self) -> usize {
        self.at
    }

    pub fn is_empty(&self) -> bool {
        self.at == 0
    }

    /// Seal what was written into an immutable view (refcounted; the
    /// pool slot returns when the last reader drops it).
    pub fn finish(self) -> BufView {
        let at = self.at;
        self.buf.freeze().slice(0..at)
    }
}

/// Bounds-checked reader over a [`BufView`]; scalar accessors mirror
/// [`Reader`], and [`Self::take_view`] yields payload bytes as a
/// zero-copy sub-view of the input.
pub struct ViewReader {
    view: BufView,
    at: usize,
}

impl ViewReader {
    pub fn new(view: BufView) -> Self {
        ViewReader { view, at: 0 }
    }

    /// Take `n` bytes as a refcounted sub-view (no copy).
    #[inline]
    pub fn take_view(&mut self, n: usize) -> Option<BufView> {
        if self.at + n > self.view.len() {
            return None;
        }
        let v = self.view.slice(self.at..self.at + n);
        self.at += n;
        Some(v)
    }

    #[inline]
    fn take_bytes(&mut self, n: usize) -> Option<&[u8]> {
        if self.at + n > self.view.len() {
            return None;
        }
        let s = &self.view.as_slice()[self.at..self.at + n];
        self.at += n;
        Some(s)
    }

    #[inline]
    pub fn u8(&mut self) -> Option<u8> {
        self.take_bytes(1).map(|b| b[0])
    }

    #[inline]
    pub fn u16(&mut self) -> Option<u16> {
        self.take_bytes(2).map(|b| u16::from_le_bytes(b.try_into().unwrap()))
    }

    #[inline]
    pub fn u32(&mut self) -> Option<u32> {
        self.take_bytes(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    }

    #[inline]
    pub fn u64(&mut self) -> Option<u64> {
        self.take_bytes(8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.view.len() - self.at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        let mut w = Writer::new();
        w.u8(1);
        w.u16(2);
        w.u32(3);
        w.u64(4);
        w.bytes(b"xyz");
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        assert_eq!(r.u8(), Some(1));
        assert_eq!(r.u16(), Some(2));
        assert_eq!(r.u32(), Some(3));
        assert_eq!(r.u64(), Some(4));
        assert_eq!(r.take(3), Some(&b"xyz"[..]));
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.u8(), None);
    }

    #[test]
    fn overread_is_none_not_panic() {
        let mut r = Reader::new(&[1, 2]);
        assert_eq!(r.u32(), None);
        // Failed read consumes nothing.
        assert_eq!(r.u16(), Some(0x0201));
    }

    #[test]
    fn pooled_writer_roundtrips_through_view_reader() {
        let pool = BufPool::new(2, 256);
        let mut w = PooledWriter::new(&pool, 32);
        w.u8(1);
        w.u16(2);
        w.u32(3);
        w.u64(4);
        w.bytes(b"xyz");
        assert_eq!(w.len(), 18);
        let view = w.finish();
        assert_eq!(view.len(), 18);
        let mut r = ViewReader::new(view.clone());
        assert_eq!(r.u8(), Some(1));
        assert_eq!(r.u16(), Some(2));
        assert_eq!(r.u32(), Some(3));
        assert_eq!(r.u64(), Some(4));
        let tail = r.take_view(3).unwrap();
        assert_eq!(tail, &b"xyz"[..]);
        assert!(tail.shares_storage(&view), "payload is a view, not a copy");
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.u8(), None);
        // Encoding into the pool slot is not a heap alloc.
        let s = pool.stats();
        assert_eq!((s.pool_hits, s.fallbacks), (1, 0));
        drop((tail, view));
        assert_eq!(pool.available(), 2);
    }

    #[test]
    fn view_reader_overread_consumes_nothing() {
        let mut r = ViewReader::new(BufView::from_vec(vec![1, 2]));
        assert_eq!(r.u32(), None);
        assert_eq!(r.take_view(3), None);
        assert_eq!(r.u16(), Some(0x0201));
    }
}
