//! Minimal little-endian byte writer/reader shared by the wire formats.

/// Append-only byte writer.
#[derive(Debug, Default)]
pub struct Writer(Vec<u8>);

impl Writer {
    pub fn new() -> Self {
        Writer(Vec::new())
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer(Vec::with_capacity(n))
    }

    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    #[inline]
    pub fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn bytes(&mut self, b: &[u8]) {
        self.0.extend_from_slice(b);
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.0
    }
}

/// Bounds-checked byte reader; every accessor returns `None` past the
/// end instead of panicking (wire data is untrusted).
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, at: 0 }
    }

    #[inline]
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.at + n > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Some(s)
    }

    #[inline]
    pub fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    #[inline]
    pub fn u16(&mut self) -> Option<u16> {
        self.take(2).map(|b| u16::from_le_bytes(b.try_into().unwrap()))
    }

    #[inline]
    pub fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    }

    #[inline]
    pub fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        let mut w = Writer::new();
        w.u8(1);
        w.u16(2);
        w.u32(3);
        w.u64(4);
        w.bytes(b"xyz");
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        assert_eq!(r.u8(), Some(1));
        assert_eq!(r.u16(), Some(2));
        assert_eq!(r.u32(), Some(3));
        assert_eq!(r.u64(), Some(4));
        assert_eq!(r.take(3), Some(&b"xyz"[..]));
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.u8(), None);
    }

    #[test]
    fn overread_is_none_not_panic() {
        let mut r = Reader::new(&[1, 2]);
        assert_eq!(r.u32(), None);
        // Failed read consumes nothing.
        assert_eq!(r.u16(), Some(0x0201));
    }
}
