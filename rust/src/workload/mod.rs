//! Workload generators (§8.1 benchmark app, §9 YCSB / GetPage@LSN).

use crate::proto::{AppRequest, NetMsg};
use crate::sim::Rng;

/// Random-file-I/O client of the §8.1 evaluation app: random offsets in
/// a fixed file, configurable I/O size, read fraction, and batching.
pub struct RandomIoGen {
    pub file_id: u32,
    pub file_bytes: u64,
    pub io_bytes: u32,
    /// Fraction of reads in [0,1]; the §8 experiments use 1.0 or 0.0.
    pub read_frac: f64,
    pub batch: usize,
    rng: Rng,
    next_msg: u64,
}

impl RandomIoGen {
    pub fn new(file_id: u32, file_bytes: u64, io_bytes: u32, read_frac: f64, batch: usize, seed: u64) -> Self {
        assert!(file_bytes >= io_bytes as u64);
        RandomIoGen { file_id, file_bytes, io_bytes, read_frac, batch, rng: Rng::new(seed), next_msg: 1 }
    }

    /// Next batched message. Offsets are aligned to the I/O size like
    /// page-granular storage traffic.
    pub fn next_msg(&mut self) -> NetMsg {
        let slots = self.file_bytes / self.io_bytes as u64;
        let mut requests = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let offset = self.rng.next_range(slots) * self.io_bytes as u64;
            let is_read = self.rng.next_f64() < self.read_frac;
            requests.push(if is_read {
                AppRequest::Read { file_id: self.file_id, offset, size: self.io_bytes }
            } else {
                let data = vec![(offset % 251) as u8; self.io_bytes as usize];
                AppRequest::Write { file_id: self.file_id, offset, data }
            });
        }
        let msg = NetMsg { msg_id: self.next_msg, requests };
        self.next_msg += 1;
        msg
    }

    /// The payload expected from a read at `offset` issued by a client
    /// whose writer used this generator's fill pattern.
    pub fn expected_fill(offset: u64, len: usize) -> Vec<u8> {
        (offset..offset + len as u64).map(|i| (i % 253) as u8).collect()
    }
}

/// YCSB-style KV workload (§9.2): uniform or hot/cold key choice.
pub struct YcsbGen {
    pub n_keys: u64,
    pub read_frac: f64,
    pub value_bytes: usize,
    pub batch: usize,
    /// `None` = uniform (the paper's §9.2 read workload);
    /// `Some((hot_keys, hot_access))` = skewed.
    pub skew: Option<(u64, f64)>,
    rng: Rng,
    next_msg: u64,
}

impl YcsbGen {
    pub fn uniform(n_keys: u64, read_frac: f64, value_bytes: usize, batch: usize, seed: u64) -> Self {
        YcsbGen { n_keys, read_frac, value_bytes, batch, skew: None, rng: Rng::new(seed), next_msg: 1 }
    }

    pub fn next_key(&mut self) -> u64 {
        match self.skew {
            None => self.rng.next_range(self.n_keys),
            Some((hot, acc)) => self.rng.hotcold(self.n_keys, hot, acc),
        }
    }

    pub fn next_msg(&mut self) -> NetMsg {
        let mut requests = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let key = self.next_key();
            let is_read = self.rng.next_f64() < self.read_frac;
            requests.push(if is_read {
                AppRequest::KvGet { key }
            } else {
                AppRequest::KvUpsert { key, value: vec![(key % 256) as u8; self.value_bytes] }
            });
        }
        let msg = NetMsg { msg_id: self.next_msg, requests };
        self.next_msg += 1;
        msg
    }
}

/// GetPage@LSN workload (§9.1): random pages; requested LSN trails the
/// latest applied LSN so a configurable fraction is DPU-serviceable.
pub struct GetPageGen {
    pub n_pages: u64,
    pub batch: usize,
    /// Current global LSN (advance with [`GetPageGen::advance_lsn`]).
    pub current_lsn: u64,
    rng: Rng,
    next_msg: u64,
}

impl GetPageGen {
    pub fn new(n_pages: u64, batch: usize, seed: u64) -> Self {
        GetPageGen { n_pages, batch, current_lsn: 1, rng: Rng::new(seed), next_msg: 1 }
    }

    pub fn advance_lsn(&mut self) -> u64 {
        self.current_lsn += 1;
        self.current_lsn
    }

    pub fn next_msg(&mut self) -> NetMsg {
        let mut requests = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let page_id = self.rng.next_range(self.n_pages);
            requests.push(AppRequest::GetPage { page_id, lsn: self.current_lsn });
        }
        let msg = NetMsg { msg_id: self.next_msg, requests };
        self.next_msg += 1;
        msg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_io_respects_bounds_and_batch() {
        let mut g = RandomIoGen::new(1, 1 << 20, 1024, 1.0, 16, 7);
        for _ in 0..100 {
            let m = g.next_msg();
            assert_eq!(m.requests.len(), 16);
            for r in &m.requests {
                match r {
                    AppRequest::Read { offset, size, .. } => {
                        assert_eq!(offset % 1024, 0);
                        assert!(offset + *size as u64 <= 1 << 20);
                    }
                    _ => panic!("read_frac=1.0 must generate only reads"),
                }
            }
        }
    }

    #[test]
    fn msg_ids_monotonic() {
        let mut g = RandomIoGen::new(1, 1 << 20, 512, 0.5, 1, 3);
        let a = g.next_msg().msg_id;
        let b = g.next_msg().msg_id;
        assert!(b > a);
    }

    #[test]
    fn ycsb_uniform_coverage() {
        let mut g = YcsbGen::uniform(100, 1.0, 8, 1, 11);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..5000 {
            seen.insert(g.next_key());
        }
        assert!(seen.len() > 95, "uniform should cover keyspace: {}", seen.len());
    }

    #[test]
    fn getpage_lsn_monotone() {
        let mut g = GetPageGen::new(64, 4, 5);
        let l1 = g.current_lsn;
        g.advance_lsn();
        assert_eq!(g.current_lsn, l1 + 1);
        let m = g.next_msg();
        for r in &m.requests {
            match r {
                AppRequest::GetPage { page_id, lsn } => {
                    assert!(*page_id < 64);
                    assert_eq!(*lsn, g.current_lsn);
                }
                _ => unreachable!(),
            }
        }
    }
}
