//! PJRT runtime: load and execute the AOT-compiled kernels.
//!
//! Python runs once at build time (`make artifacts`): Layer-2 JAX
//! programs calling Layer-1 Pallas kernels are lowered to **HLO text**
//! (`artifacts/*.hlo.txt`) by `python/compile/aot.py`. This module loads
//! each artifact into a PJRT CPU client and executes it from the rust
//! hot path — Python is never on the request path.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::cache::DenseTable;

/// A PJRT client plus the loaded kernel executables.
pub struct KernelRuntime {
    client: xla::PjRtClient,
    kernels: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// The standard artifact directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// Batch size the predicate kernel is AOT-compiled for.
pub const PREDICATE_BATCH: usize = 1024;
/// Dense table slots the predicate kernel is AOT-compiled for.
pub const PREDICATE_SLOTS: usize = 8192;
/// Page bytes the checksum kernel is AOT-compiled for.
pub const CHECKSUM_PAGE: usize = 8192;
/// Pages per checksum batch.
pub const CHECKSUM_BATCH: usize = 16;

impl KernelRuntime {
    /// Create a CPU PJRT client with no kernels loaded.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(KernelRuntime { client, kernels: HashMap::new() })
    }

    /// Load one HLO-text artifact under `name`.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.kernels.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load every `<name>.hlo.txt` in a directory.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let mut loaded = Vec::new();
        for entry in std::fs::read_dir(dir).with_context(|| format!("read {dir:?}"))? {
            let path = entry?.path();
            let fname = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if let Some(name) = fname.strip_suffix(".hlo.txt") {
                self.load(name, &path)?;
                loaded.push(name.to_string());
            }
        }
        loaded.sort();
        Ok(loaded)
    }

    /// Locate the artifacts directory: `$DDS_ARTIFACTS` or ./artifacts.
    pub fn artifacts_dir() -> PathBuf {
        std::env::var_os("DDS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from(DEFAULT_ARTIFACTS))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.kernels.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    fn kernel(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.kernels
            .get(name)
            .ok_or_else(|| anyhow!("kernel {name} not loaded (run `make artifacts`)"))
    }

    /// Execute the offload-predicate kernel (`predicate.hlo.txt`) on up
    /// to [`PREDICATE_BATCH`] queries against a dense cache-table
    /// snapshot whose slot count must equal [`PREDICATE_SLOTS`].
    ///
    /// Returns, per query: `(offload, item_a, item_b, item_c, item_d)` —
    /// for GetPage@LSN offloading, `offload = found && cached_lsn >=
    /// req_lsn` and the items carry `(lsn, file_id, offset, size)`.
    pub fn predicate_batch(
        &self,
        table: &DenseTable,
        keys: &[u64],
        lsns: &[u64],
    ) -> Result<Vec<PredicateHit>> {
        anyhow::ensure!(keys.len() == lsns.len(), "keys/lsns length mismatch");
        anyhow::ensure!(keys.len() <= PREDICATE_BATCH, "batch too large");
        anyhow::ensure!(
            table.keys.len() == PREDICATE_SLOTS,
            "table has {} slots; kernel compiled for {}",
            table.keys.len(),
            PREDICATE_SLOTS
        );
        let exe = self.kernel("predicate")?;
        // Pad the batch to the compiled shape with never-matching keys.
        let mut qk = vec![crate::cache::EMPTY - 1; PREDICATE_BATCH];
        let mut ql = vec![u64::MAX; PREDICATE_BATCH];
        qk[..keys.len()].copy_from_slice(keys);
        ql[..lsns.len()].copy_from_slice(lsns);

        let t_keys = xla::Literal::vec1(&table.keys);
        let t_items = xla::Literal::vec1(&table.items)
            .reshape(&[PREDICATE_SLOTS as i64, 4])
            .map_err(|e| anyhow!("reshape items: {e:?}"))?;
        let l_keys = xla::Literal::vec1(&qk);
        let l_lsns = xla::Literal::vec1(&ql);

        let result = exe
            .execute::<xla::Literal>(&[t_keys, t_items, l_keys, l_lsns])
            .map_err(|e| anyhow!("execute predicate: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (mask, a, b, cd) = result
            .to_tuple4()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        let mask = mask.to_vec::<u64>().map_err(|e| anyhow!("mask: {e:?}"))?;
        let a = a.to_vec::<u64>().map_err(|e| anyhow!("a: {e:?}"))?;
        let b = b.to_vec::<u64>().map_err(|e| anyhow!("b: {e:?}"))?;
        let cd = cd.to_vec::<u64>().map_err(|e| anyhow!("cd: {e:?}"))?;
        // cd packs (c,d) as [B, 2].
        let mut out = Vec::with_capacity(keys.len());
        for i in 0..keys.len() {
            out.push(PredicateHit {
                offload: mask[i] != 0,
                a: a[i],
                b: b[i],
                c: cd[2 * i],
                d: cd[2 * i + 1],
            });
        }
        Ok(out)
    }

    /// Execute the page-checksum kernel (`checksum.hlo.txt`) over a
    /// batch of [`CHECKSUM_BATCH`] pages of [`CHECKSUM_PAGE`] bytes.
    /// Returns one 64-bit Fletcher-style checksum per page.
    pub fn checksum_batch(&self, pages: &[u8]) -> Result<Vec<u64>> {
        anyhow::ensure!(
            pages.len() == CHECKSUM_BATCH * CHECKSUM_PAGE,
            "expected {} bytes",
            CHECKSUM_BATCH * CHECKSUM_PAGE
        );
        let exe = self.kernel("checksum")?;
        // u8 → u32 words on the rust side (stable layout for the kernel).
        let words: Vec<u32> = pages
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let lit = xla::Literal::vec1(&words)
            .reshape(&[CHECKSUM_BATCH as i64, (CHECKSUM_PAGE / 4) as i64])
            .map_err(|e| anyhow!("reshape pages: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute checksum: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let sums = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?
            .to_vec::<u64>()
            .map_err(|e| anyhow!("sums: {e:?}"))?;
        Ok(sums)
    }
}

/// One predicate-kernel result row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PredicateHit {
    pub offload: bool,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub d: u64,
}

/// Reference checksum matching the kernel (and `kernels/ref.py`):
/// Fletcher-style over little-endian u32 words, mod 2^32 lanes packed
/// into a u64.
pub fn checksum_ref(page: &[u8]) -> u64 {
    let mut s1: u64 = 0;
    let mut s2: u64 = 0;
    for c in page.chunks_exact(4) {
        let w = u32::from_le_bytes(c.try_into().unwrap()) as u64;
        s1 = (s1 + w) & 0xffff_ffff;
        s2 = (s2 + s1) & 0xffff_ffff;
    }
    s2 << 32 | s1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_ref_properties() {
        let a = checksum_ref(&[0u8; 64]);
        assert_eq!(a, 0);
        let mut page = vec![0u8; 64];
        page[0] = 1;
        let b = checksum_ref(&page);
        assert_ne!(b, 0);
        // Order sensitivity (s2 lane).
        let mut p1 = vec![0u8; 8];
        p1[0] = 1;
        let mut p2 = vec![0u8; 8];
        p2[4] = 1;
        assert_ne!(checksum_ref(&p1), checksum_ref(&p2));
    }

    #[test]
    fn artifacts_dir_env_override() {
        // Default (no env var set in tests unless CI sets it).
        let d = KernelRuntime::artifacts_dir();
        assert!(d.as_os_str().len() > 0);
    }
}
