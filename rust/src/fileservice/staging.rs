//! Ordered response staging — the TailA/TailB/TailC machinery of §4.3.
//!
//! * `TailA` (**allocated**): end of pre-allocated response slots; a
//!   slot is allocated, with status *pending*, **before** its I/O is
//!   submitted, so the SSD DMA has a destination and no response copy is
//!   ever needed.
//! * `TailB` (**buffered**): end of the in-order prefix of completed
//!   responses. The service "periodically checks the status of the
//!   pre-allocated responses ... advances TailB until a pending
//!   response".
//! * `TailC` (**completed/delivered**): end of responses DMA-written to
//!   the host response ring. `TailB - TailC ≥ batch` triggers delivery.

use std::time::{Duration, Instant};

use crate::dpufs::Extent;

/// Status of one pre-allocated response slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StagedStatus {
    Pending,
    Done,
    Failed,
}

#[derive(Debug)]
struct Slot {
    req_id: u64,
    status: StagedStatus,
    /// Pre-allocated response payload buffer (read data lands here).
    data: Vec<u8>,
    extents_remaining: usize,
    /// Byte offset in `data` where each extent starts.
    extent_offsets: Vec<usize>,
    /// Allocation time — reference point for [`OrderedStaging::fail_stalled`].
    issued: Instant,
}

/// Fixed-capacity ring of pre-allocated response slots with the three
/// tail pointers.
pub struct OrderedStaging {
    slots: Vec<Option<Slot>>,
    /// TailA: next slot to allocate (monotonic).
    tail_a: u64,
    /// TailB: end of in-order completed prefix.
    tail_b: u64,
    /// TailC: delivered to the host.
    tail_c: u64,
}

impl OrderedStaging {
    pub fn new(capacity: usize) -> Self {
        let mut slots = Vec::with_capacity(capacity);
        slots.resize_with(capacity, || None);
        OrderedStaging { slots, tail_a: 0, tail_b: 0, tail_c: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn free_slots(&self) -> usize {
        self.capacity() - (self.tail_a - self.tail_c) as usize
    }

    /// Completed-but-undelivered responses (`TailB - TailC`).
    pub fn buffered(&self) -> usize {
        (self.tail_b - self.tail_c) as usize
    }

    /// Allocated-but-not-complete (`TailA - TailB`).
    pub fn outstanding(&self) -> usize {
        (self.tail_a - self.tail_b) as usize
    }

    /// TailA advance: pre-allocate a response of `expected_len` payload
    /// bytes for `req_id`, status pending. Returns the slot index, or
    /// `None` when the ring is full.
    pub fn allocate(&mut self, req_id: u64, expected_len: usize) -> Option<u64> {
        if self.free_slots() == 0 {
            return None;
        }
        let idx = self.tail_a;
        let pos = (idx % self.capacity() as u64) as usize;
        // expected_len counts header + payload; the payload buffer is
        // what the device writes into.
        let payload = expected_len.saturating_sub(crate::proto::FileResponse::HEADER_LEN);
        self.slots[pos] = Some(Slot {
            req_id,
            status: StagedStatus::Pending,
            data: vec![0u8; payload],
            extents_remaining: usize::MAX, // until set_extents
            extent_offsets: Vec::new(),
            issued: Instant::now(),
        });
        self.tail_a += 1;
        Some(idx)
    }

    /// Record the extent layout for a slot (defines where each extent's
    /// bytes land in the pre-allocated buffer).
    pub fn set_extents(&mut self, slot: u64, extents: &[Extent]) {
        let pos = (slot % self.capacity() as u64) as usize;
        let s = self.slots[pos].as_mut().expect("slot allocated");
        let mut offsets = Vec::with_capacity(extents.len());
        let mut acc = 0usize;
        for e in extents {
            offsets.push(acc);
            acc += e.len as usize;
        }
        s.extent_offsets = offsets;
        s.extents_remaining = extents.len();
        if extents.is_empty() {
            s.status = StagedStatus::Done;
        }
    }

    /// Mark one extent of `slot` complete, placing `data` at its
    /// recorded offset. `extra_copy` models the straw-man that stages
    /// the payload once more before placing it (Fig 18 ablation).
    pub fn complete_extent(&mut self, slot: u64, extent: usize, data: &[u8], extra_copy: bool) {
        if slot < self.tail_c || slot >= self.tail_a {
            return; // stale completion
        }
        let pos = (slot % self.capacity() as u64) as usize;
        let Some(s) = self.slots[pos].as_mut() else { return };
        if s.status == StagedStatus::Failed {
            return;
        }
        let staged;
        let src: &[u8] = if extra_copy {
            staged = data.to_vec();
            &staged
        } else {
            data
        };
        if !src.is_empty() {
            let start = s.extent_offsets.get(extent).copied().unwrap_or(0);
            let end = (start + src.len()).min(s.data.len());
            if start < end {
                s.data[start..end].copy_from_slice(&src[..end - start]);
            }
        }
        s.extents_remaining = s.extents_remaining.saturating_sub(1);
        if s.extents_remaining == 0 {
            s.status = StagedStatus::Done;
        }
    }

    /// Mark a slot failed (error code instead of pending, §4.3).
    /// Stale failures — a late error completion for a slot index that
    /// was already delivered (e.g. aborted by [`Self::fail_stalled`])
    /// and since recycled — are ignored, exactly like stale successes
    /// in [`Self::complete_extent`].
    pub fn fail(&mut self, slot: u64) {
        if slot < self.tail_c || slot >= self.tail_a {
            return; // stale completion for a recycled slot index
        }
        let pos = (slot % self.capacity() as u64) as usize;
        if let Some(s) = self.slots[pos].as_mut() {
            s.status = StagedStatus::Failed;
        }
    }

    /// Lost-completion recovery: fail slots at the front of the pending
    /// window (`TailB`) that have sat pending longer than `timeout`, so
    /// one lost SSD completion can't block in-order delivery forever.
    /// Only the window head needs checking — a stuck slot behind a
    /// stuck head becomes the head once the first is failed. Returns
    /// how many slots were aborted.
    pub fn fail_stalled(&mut self, timeout: Duration) -> usize {
        let mut failed = 0;
        loop {
            self.advance_buffered();
            if self.tail_b >= self.tail_a {
                return failed;
            }
            let pos = (self.tail_b % self.capacity() as u64) as usize;
            match self.slots[pos].as_mut() {
                Some(s) if s.status == StagedStatus::Pending
                    && s.issued.elapsed() >= timeout =>
                {
                    s.status = StagedStatus::Failed;
                    failed += 1;
                }
                _ => return failed,
            }
        }
    }

    /// TailB advance: extend the in-order completed prefix.
    pub fn advance_buffered(&mut self) {
        while self.tail_b < self.tail_a {
            let pos = (self.tail_b % self.capacity() as u64) as usize;
            match self.slots[pos].as_ref() {
                Some(s) if s.status != StagedStatus::Pending => self.tail_b += 1,
                _ => break,
            }
        }
    }

    /// Next deliverable response (at TailC), if TailC < TailB.
    pub fn peek_deliverable(&self) -> Option<(u64, StagedStatus, Vec<u8>)> {
        if self.tail_c >= self.tail_b {
            return None;
        }
        let pos = (self.tail_c % self.capacity() as u64) as usize;
        let s = self.slots[pos].as_ref().expect("slot in [TailC, TailB)");
        let data = if s.status == StagedStatus::Done { s.data.clone() } else { Vec::new() };
        Some((s.req_id, s.status, data))
    }

    /// TailC advance after a successful DMA-write to the host ring.
    pub fn pop_delivered(&mut self) {
        assert!(self.tail_c < self.tail_b, "nothing deliverable");
        let pos = (self.tail_c % self.capacity() as u64) as usize;
        self.slots[pos] = None;
        self.tail_c += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ext(addr: u64, len: u64) -> Extent {
        Extent { addr, len }
    }

    #[test]
    fn in_order_single_extent() {
        let mut st = OrderedStaging::new(8);
        let a = st.allocate(1, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        let b = st.allocate(2, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        st.set_extents(a, &[ext(0, 4)]);
        st.set_extents(b, &[ext(4, 4)]);
        // Complete b FIRST — must not be delivered before a.
        st.complete_extent(b, 0, &[2, 2, 2, 2], false);
        st.advance_buffered();
        assert_eq!(st.buffered(), 0);
        assert!(st.peek_deliverable().is_none());
        // Complete a — now both become deliverable in order.
        st.complete_extent(a, 0, &[1, 1, 1, 1], false);
        st.advance_buffered();
        assert_eq!(st.buffered(), 2);
        let (id1, s1, d1) = st.peek_deliverable().unwrap();
        assert_eq!((id1, s1, d1), (1, StagedStatus::Done, vec![1, 1, 1, 1]));
        st.pop_delivered();
        let (id2, _, d2) = st.peek_deliverable().unwrap();
        assert_eq!((id2, d2), (2, vec![2, 2, 2, 2]));
        st.pop_delivered();
        assert!(st.peek_deliverable().is_none());
    }

    #[test]
    fn multi_extent_assembles_at_offsets() {
        let mut st = OrderedStaging::new(4);
        let a = st.allocate(7, crate::proto::FileResponse::HEADER_LEN + 10).unwrap();
        st.set_extents(a, &[ext(0, 6), ext(100, 4)]);
        // Second extent completes first.
        st.complete_extent(a, 1, &[9, 9, 9, 9], false);
        st.advance_buffered();
        assert_eq!(st.buffered(), 0);
        st.complete_extent(a, 0, &[1, 2, 3, 4, 5, 6], false);
        st.advance_buffered();
        let (_, status, data) = st.peek_deliverable().unwrap();
        assert_eq!(status, StagedStatus::Done);
        assert_eq!(data, vec![1, 2, 3, 4, 5, 6, 9, 9, 9, 9]);
    }

    #[test]
    fn capacity_enforced() {
        let mut st = OrderedStaging::new(2);
        st.allocate(1, 16).unwrap();
        st.allocate(2, 16).unwrap();
        assert!(st.allocate(3, 16).is_none());
        assert_eq!(st.free_slots(), 0);
    }

    #[test]
    fn failed_slot_delivers_error_in_order() {
        let mut st = OrderedStaging::new(4);
        let a = st.allocate(1, 32).unwrap();
        st.set_extents(a, &[ext(0, 19)]);
        st.fail(a);
        st.advance_buffered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!(id, 1);
        assert_eq!(status, StagedStatus::Failed);
        assert!(data.is_empty());
    }

    #[test]
    fn stale_completion_ignored() {
        let mut st = OrderedStaging::new(2);
        let a = st.allocate(1, 16).unwrap();
        st.set_extents(a, &[ext(0, 3)]);
        st.complete_extent(a, 0, &[1, 2, 3], false);
        st.advance_buffered();
        st.pop_delivered();
        // Late duplicate completion for a recycled slot index: no panic,
        // no state corruption.
        st.complete_extent(a, 0, &[9, 9, 9], false);
        assert_eq!(st.buffered(), 0);
        // A late ERROR completion for the delivered slot is equally
        // stale: slot index 2 recycles slot 0's ring position, and a
        // late fail(0) must not mark that healthy new occupant Failed.
        let b = st.allocate(2, 16).unwrap();
        let c = st.allocate(3, 16).unwrap();
        assert_eq!(c % 2, a % 2, "c recycles a's ring position");
        st.set_extents(b, &[ext(0, 3)]);
        st.set_extents(c, &[ext(4, 3)]);
        st.fail(a);
        st.complete_extent(b, 0, &[7, 7, 7], false);
        st.complete_extent(c, 0, &[8, 8, 8], false);
        st.advance_buffered();
        let (id, status, _) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (2, StagedStatus::Done));
        st.pop_delivered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (3, StagedStatus::Done), "stale fail hit the new occupant");
        assert_eq!(data, vec![8, 8, 8]);
    }

    #[test]
    fn fail_stalled_unblocks_in_order_delivery() {
        let mut st = OrderedStaging::new(8);
        let a = st.allocate(1, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        let b = st.allocate(2, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        st.set_extents(a, &[ext(0, 4)]);
        st.set_extents(b, &[ext(4, 4)]);
        // b completes; a's completion is lost. Nothing deliverable yet.
        st.complete_extent(b, 0, &[2, 2, 2, 2], false);
        assert_eq!(st.fail_stalled(Duration::from_secs(60)), 0, "not stalled yet");
        st.advance_buffered();
        assert!(st.peek_deliverable().is_none());
        // Timeout elapses (zero budget): a is aborted, both deliver in
        // order — a as Failed, b as Done.
        assert_eq!(st.fail_stalled(Duration::ZERO), 1);
        st.advance_buffered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (1, StagedStatus::Failed));
        assert!(data.is_empty());
        st.pop_delivered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (2, StagedStatus::Done));
        assert_eq!(data, vec![2, 2, 2, 2]);
        // A completed head is never aborted.
        let c = st.allocate(3, crate::proto::FileResponse::HEADER_LEN).unwrap();
        st.set_extents(c, &[ext(8, 4)]);
        st.complete_extent(c, 0, &[], false);
        assert_eq!(st.fail_stalled(Duration::ZERO), 0);
    }

    #[test]
    fn write_slot_zero_extents_completes_via_counter() {
        let mut st = OrderedStaging::new(2);
        let a = st.allocate(5, crate::proto::FileResponse::HEADER_LEN).unwrap();
        st.set_extents(a, &[ext(0, 8)]);
        st.complete_extent(a, 0, &[], false); // write completion: no data
        st.advance_buffered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (5, StagedStatus::Done));
        assert!(data.is_empty());
    }
}
