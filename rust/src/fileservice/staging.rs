//! Ordered response staging — the TailA/TailB/TailC machinery of §4.3.
//!
//! * `TailA` (**allocated**): end of pre-allocated response slots; a
//!   slot is allocated, with status *pending*, **before** its I/O is
//!   submitted, so the SSD DMA has a destination and no response copy is
//!   ever needed.
//! * `TailB` (**buffered**): end of the in-order prefix of completed
//!   responses. The service "periodically checks the status of the
//!   pre-allocated responses ... advances TailB until a pending
//!   response".
//! * `TailC` (**completed/delivered**): end of responses DMA-written to
//!   the host response ring. `TailB - TailC ≥ batch` triggers delivery.
//!
//! Zero-copy: a single-extent read (the common case) completes by
//! *referencing* the pooled buffer the SSD DMA'd into — the slot holds
//! a [`BufView`], and delivery DMA-writes that view (vectored with the
//! response header) straight to the host ring. Only multi-extent reads
//! gather into a pool-backed assembly buffer (a metered copy), and only
//! the `extra_copy` straw-man (Fig 18 ablation) stages payloads twice.

use std::time::{Duration, Instant};

use crate::buf::{BufPool, BufView, PooledBuf};
use crate::dpufs::Extent;

/// Status of one pre-allocated response slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StagedStatus {
    Pending,
    Done,
    Failed,
}

#[derive(Debug)]
struct Slot {
    req_id: u64,
    status: StagedStatus,
    /// Completed payload: for single-extent reads, the completion view
    /// itself; for multi-extent reads, the frozen assembly buffer.
    view: Option<BufView>,
    /// Multi-extent gather buffer (pool-backed), allocated when the
    /// extent layout is recorded and frozen into `view` on completion.
    assembly: Option<PooledBuf>,
    /// Expected payload bytes (0 for writes).
    expected_payload: usize,
    extents_remaining: usize,
    /// Byte offset where each extent's bytes land in the payload.
    extent_offsets: Vec<usize>,
    /// Durable-WRITE gate: when set, the last extent completion leaves
    /// the slot *commit-ready* (still Pending) instead of Done — only
    /// [`OrderedStaging::commit_done`], called once the remap record is
    /// durably journaled, makes it deliverable. The ack point moves
    /// from "payload landed" to "commit record appended".
    gated: bool,
    /// Allocation time — reference point for [`OrderedStaging::fail_stalled`].
    issued: Instant,
}

/// Fixed-capacity ring of pre-allocated response slots with the three
/// tail pointers.
pub struct OrderedStaging {
    slots: Vec<Option<Slot>>,
    pool: BufPool,
    /// TailA: next slot to allocate (monotonic).
    tail_a: u64,
    /// TailB: end of in-order completed prefix.
    tail_b: u64,
    /// TailC: delivered to the host.
    tail_c: u64,
}

impl OrderedStaging {
    /// `pool` backs multi-extent assembly and straw-man staging copies.
    pub fn new(capacity: usize, pool: BufPool) -> Self {
        let mut slots = Vec::with_capacity(capacity);
        slots.resize_with(capacity, || None);
        OrderedStaging { slots, pool, tail_a: 0, tail_b: 0, tail_c: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn free_slots(&self) -> usize {
        self.capacity() - (self.tail_a - self.tail_c) as usize
    }

    /// Completed-but-undelivered responses (`TailB - TailC`).
    pub fn buffered(&self) -> usize {
        (self.tail_b - self.tail_c) as usize
    }

    /// Allocated-but-not-complete (`TailA - TailB`).
    pub fn outstanding(&self) -> usize {
        (self.tail_a - self.tail_b) as usize
    }

    /// TailA advance: pre-allocate a response of `expected_len` total
    /// bytes (header + payload) for `req_id`, status pending. Returns
    /// the slot index, or `None` when the ring is full.
    ///
    /// "Pre-allocation" here reserves the *slot*; the payload memory
    /// itself is the pooled buffer the SSD completion arrives in (the
    /// zero-copy contract), so nothing is allocated up front.
    pub fn allocate(&mut self, req_id: u64, expected_len: usize) -> Option<u64> {
        if self.free_slots() == 0 {
            return None;
        }
        let idx = self.tail_a;
        let pos = (idx % self.capacity() as u64) as usize;
        let payload = expected_len.saturating_sub(crate::proto::FileResponse::HEADER_LEN);
        self.slots[pos] = Some(Slot {
            req_id,
            status: StagedStatus::Pending,
            view: None,
            assembly: None,
            expected_payload: payload,
            extents_remaining: usize::MAX, // until set_extents
            extent_offsets: Vec::new(),
            gated: false,
            issued: Instant::now(),
        });
        self.tail_a += 1;
        Some(idx)
    }

    /// Record the extent layout for a slot (defines where each extent's
    /// bytes land in the payload). Multi-extent reads allocate their
    /// gather buffer here.
    pub fn set_extents(&mut self, slot: u64, extents: &[Extent]) {
        let pos = (slot % self.capacity() as u64) as usize;
        let s = self.slots[pos].as_mut().expect("slot allocated");
        let mut offsets = Vec::with_capacity(extents.len());
        let mut acc = 0usize;
        for e in extents {
            offsets.push(acc);
            acc += e.len as usize;
        }
        s.extent_offsets = offsets;
        s.extents_remaining = extents.len();
        if extents.len() > 1 && s.expected_payload > 0 {
            s.assembly = Some(self.pool.allocate(s.expected_payload.min(acc)));
        }
        if extents.is_empty() {
            s.status = StagedStatus::Done;
        }
    }

    /// Mark one extent of `slot` complete. Single-extent reads keep a
    /// reference to `data` (zero-copy); multi-extent reads gather it at
    /// the recorded offset (metered copy). `extra_copy` models the
    /// straw-man that stages the payload once more before placing it
    /// (Fig 18 ablation; also metered).
    pub fn complete_extent(&mut self, slot: u64, extent: usize, data: &BufView, extra_copy: bool) {
        if slot < self.tail_c || slot >= self.tail_a {
            return; // stale completion
        }
        let pos = (slot % self.capacity() as u64) as usize;
        let Some(s) = self.slots[pos].as_mut() else { return };
        if s.status == StagedStatus::Failed {
            return;
        }
        let src: BufView = if extra_copy && !data.is_empty() {
            BufView::copy_of(&self.pool, data.as_slice())
        } else {
            // LINT: copy-ok(BufView clone is a refcount bump, not a byte copy)
            data.clone()
        };
        if !src.is_empty() && s.expected_payload > 0 {
            if let Some(assembly) = s.assembly.as_mut() {
                // Multi-extent gather into the pre-allocated buffer.
                let start = s.extent_offsets.get(extent).copied().unwrap_or(0);
                let end = (start + src.len()).min(assembly.len());
                if start < end {
                    assembly.as_mut_slice()[start..end].copy_from_slice(&src[..end - start]);
                    self.pool.ledger().count_copy(end - start);
                }
            } else {
                // Single extent: the completion buffer IS the response
                // payload — referenced, never copied.
                let take = src.len().min(s.expected_payload);
                s.view = Some(if take == src.len() { src } else { src.slice(0..take) });
            }
        }
        s.extents_remaining = s.extents_remaining.saturating_sub(1);
        if s.extents_remaining == 0 && !s.gated {
            s.status = StagedStatus::Done;
            if let Some(assembly) = s.assembly.take() {
                s.view = Some(assembly.freeze());
            }
        }
    }

    /// Gate a slot's completion on an explicit durability commit (call
    /// after [`Self::set_extents`]): when the last extent lands the
    /// slot becomes *commit-ready* instead of Done, and only
    /// [`Self::commit_done`] delivers it. Failure paths ([`Self::fail`],
    /// [`Self::fail_stalled`]) abort a gated slot like any other.
    pub fn set_gated(&mut self, slot: u64) {
        if slot < self.tail_c || slot >= self.tail_a {
            return;
        }
        let pos = (slot % self.capacity() as u64) as usize;
        if let Some(s) = self.slots[pos].as_mut() {
            s.gated = true;
        }
    }

    /// Is `slot` a gated slot whose every extent has completed, now
    /// waiting on its durability commit?
    pub fn commit_ready(&self, slot: u64) -> bool {
        if slot < self.tail_c || slot >= self.tail_a {
            return false;
        }
        let pos = (slot % self.capacity() as u64) as usize;
        matches!(
            self.slots[pos].as_ref(),
            Some(s) if s.gated
                && s.status == StagedStatus::Pending
                && s.extents_remaining == 0
        )
    }

    /// Commit acknowledgement for a commit-ready slot: the remap record
    /// is durably journaled, so the response may be delivered. Stale or
    /// non-ready slots are ignored (same contract as completions).
    pub fn commit_done(&mut self, slot: u64) {
        if !self.commit_ready(slot) {
            return;
        }
        let pos = (slot % self.capacity() as u64) as usize;
        let s = self.slots[pos].as_mut().expect("commit_ready slot occupied");
        s.status = StagedStatus::Done;
        if let Some(assembly) = s.assembly.take() {
            s.view = Some(assembly.freeze());
        }
    }

    /// Mark a slot failed (error code instead of pending, §4.3).
    /// Stale failures — a late error completion for a slot index that
    /// was already delivered (e.g. aborted by [`Self::fail_stalled`])
    /// and since recycled — are ignored, exactly like stale successes
    /// in [`Self::complete_extent`].
    pub fn fail(&mut self, slot: u64) {
        if slot < self.tail_c || slot >= self.tail_a {
            return; // stale completion for a recycled slot index
        }
        let pos = (slot % self.capacity() as u64) as usize;
        if let Some(s) = self.slots[pos].as_mut() {
            s.status = StagedStatus::Failed;
            // Release buffers early: a failed slot delivers no payload.
            s.view = None;
            s.assembly = None;
        }
    }

    /// Lost-completion recovery: fail slots at the front of the pending
    /// window (`TailB`) that have sat pending longer than `timeout`, so
    /// one lost SSD completion can't block in-order delivery forever.
    /// Only the window head needs checking — a stuck slot behind a
    /// stuck head becomes the head once the first is failed. Returns
    /// the aborted slot indices so the caller can roll back any
    /// resources keyed to them (e.g. a gated WRITE's redirect plan).
    pub fn fail_stalled(&mut self, timeout: Duration) -> Vec<u64> {
        let mut failed = Vec::new();
        loop {
            self.advance_buffered();
            if self.tail_b >= self.tail_a {
                return failed;
            }
            let pos = (self.tail_b % self.capacity() as u64) as usize;
            match self.slots[pos].as_mut() {
                Some(s) if s.status == StagedStatus::Pending
                    && s.issued.elapsed() >= timeout =>
                {
                    s.status = StagedStatus::Failed;
                    s.view = None;
                    s.assembly = None;
                    failed.push(self.tail_b);
                }
                _ => return failed,
            }
        }
    }

    /// TailB advance: extend the in-order completed prefix.
    pub fn advance_buffered(&mut self) {
        while self.tail_b < self.tail_a {
            let pos = (self.tail_b % self.capacity() as u64) as usize;
            match self.slots[pos].as_ref() {
                Some(s) if s.status != StagedStatus::Pending => self.tail_b += 1,
                _ => break,
            }
        }
    }

    /// Next deliverable response (at TailC), if TailC < TailB. The
    /// payload comes back as a view (refcount bump) — delivery pushes
    /// it to the host ring without materializing.
    pub fn peek_deliverable(&self) -> Option<(u64, StagedStatus, BufView)> {
        self.peek_deliverable_at(0)
    }

    /// The `k`-th deliverable response past TailC (`k < buffered()`),
    /// letting delivery gather the whole `[TailC, TailB)` window into
    /// one burst push without advancing any tail.
    pub fn peek_deliverable_at(&self, k: usize) -> Option<(u64, StagedStatus, BufView)> {
        let idx = self.tail_c + k as u64;
        if idx >= self.tail_b {
            return None;
        }
        let pos = (idx % self.capacity() as u64) as usize;
        let s = self.slots[pos].as_ref().expect("slot in [TailC, TailB)");
        let data = match (&s.status, &s.view) {
            (StagedStatus::Done, Some(v)) => v.clone(),
            _ => BufView::empty(),
        };
        Some((s.req_id, s.status, data))
    }

    /// TailC advance after a successful DMA-write to the host ring.
    /// Drops the slot's view — the pooled buffer goes home once the
    /// last reference (e.g. an in-flight vectored push) releases.
    /// Returns when the slot was allocated, so the caller can meter
    /// admission-to-delivery service latency.
    pub fn pop_delivered(&mut self) -> Instant {
        assert!(self.tail_c < self.tail_b, "nothing deliverable");
        let pos = (self.tail_c % self.capacity() as u64) as usize;
        let issued =
            self.slots[pos].take().expect("slot in [TailC, TailB)").issued;
        self.tail_c += 1;
        issued
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ext(addr: u64, len: u64) -> Extent {
        Extent { addr, len }
    }

    fn staging(capacity: usize) -> OrderedStaging {
        OrderedStaging::new(capacity, BufPool::new(capacity, 4096))
    }

    fn view(bytes: &[u8]) -> BufView {
        BufView::from_vec(bytes.to_vec())
    }

    #[test]
    fn in_order_single_extent() {
        let mut st = staging(8);
        let a = st.allocate(1, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        let b = st.allocate(2, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        st.set_extents(a, &[ext(0, 4)]);
        st.set_extents(b, &[ext(4, 4)]);
        // Complete b FIRST — must not be delivered before a.
        st.complete_extent(b, 0, &view(&[2, 2, 2, 2]), false);
        st.advance_buffered();
        assert_eq!(st.buffered(), 0);
        assert!(st.peek_deliverable().is_none());
        // Complete a — now both become deliverable in order.
        st.complete_extent(a, 0, &view(&[1, 1, 1, 1]), false);
        st.advance_buffered();
        assert_eq!(st.buffered(), 2);
        let (id1, s1, d1) = st.peek_deliverable().unwrap();
        assert_eq!((id1, s1), (1, StagedStatus::Done));
        assert_eq!(d1, vec![1, 1, 1, 1]);
        st.pop_delivered();
        let (id2, _, d2) = st.peek_deliverable().unwrap();
        assert_eq!(id2, 2);
        assert_eq!(d2, vec![2, 2, 2, 2]);
        st.pop_delivered();
        assert!(st.peek_deliverable().is_none());
    }

    /// Single-extent completion is zero-copy: the delivered payload
    /// aliases the completion buffer and the staging pool meters
    /// nothing.
    #[test]
    fn single_extent_references_completion_buffer() {
        let pool = BufPool::new(4, 4096);
        let mut st = OrderedStaging::new(4, pool.clone());
        let a = st.allocate(1, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        st.set_extents(a, &[ext(0, 4)]);
        let completion = view(&[9, 8, 7, 6]);
        st.complete_extent(a, 0, &completion, false);
        st.advance_buffered();
        let (_, status, data) = st.peek_deliverable().unwrap();
        assert_eq!(status, StagedStatus::Done);
        assert!(data.shares_storage(&completion), "referenced, not copied");
        let s = pool.stats();
        assert_eq!((s.allocs, s.bytes_copied), (0, 0));
    }

    #[test]
    fn multi_extent_assembles_at_offsets() {
        let pool = BufPool::new(4, 4096);
        let mut st = OrderedStaging::new(4, pool.clone());
        let a = st.allocate(7, crate::proto::FileResponse::HEADER_LEN + 10).unwrap();
        st.set_extents(a, &[ext(0, 6), ext(100, 4)]);
        // Second extent completes first.
        st.complete_extent(a, 1, &view(&[9, 9, 9, 9]), false);
        st.advance_buffered();
        assert_eq!(st.buffered(), 0);
        st.complete_extent(a, 0, &view(&[1, 2, 3, 4, 5, 6]), false);
        st.advance_buffered();
        let (_, status, data) = st.peek_deliverable().unwrap();
        assert_eq!(status, StagedStatus::Done);
        assert_eq!(data, vec![1, 2, 3, 4, 5, 6, 9, 9, 9, 9]);
        // The gather is metered: one pooled assembly, 10 bytes copied.
        let s = pool.stats();
        assert_eq!((s.allocs, s.bytes_copied), (1, 10));
    }

    #[test]
    fn extra_copy_mode_is_metered() {
        let pool = BufPool::new(4, 4096);
        let mut st = OrderedStaging::new(4, pool.clone());
        let a = st.allocate(1, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        st.set_extents(a, &[ext(0, 4)]);
        st.complete_extent(a, 0, &view(&[5, 5, 5, 5]), true);
        st.advance_buffered();
        let (_, status, data) = st.peek_deliverable().unwrap();
        assert_eq!(status, StagedStatus::Done);
        assert_eq!(data, vec![5, 5, 5, 5]);
        assert_eq!(pool.stats().bytes_copied, 4, "the straw-man staging copy");
    }

    #[test]
    fn capacity_enforced() {
        let mut st = staging(2);
        st.allocate(1, 16).unwrap();
        st.allocate(2, 16).unwrap();
        assert!(st.allocate(3, 16).is_none());
        assert_eq!(st.free_slots(), 0);
    }

    #[test]
    fn failed_slot_delivers_error_in_order() {
        let mut st = staging(4);
        let a = st.allocate(1, 32).unwrap();
        st.set_extents(a, &[ext(0, 19)]);
        st.fail(a);
        st.advance_buffered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!(id, 1);
        assert_eq!(status, StagedStatus::Failed);
        assert!(data.is_empty());
    }

    #[test]
    fn stale_completion_ignored() {
        let mut st = staging(2);
        let a = st.allocate(1, 16).unwrap();
        st.set_extents(a, &[ext(0, 3)]);
        st.complete_extent(a, 0, &view(&[1, 2, 3]), false);
        st.advance_buffered();
        st.pop_delivered();
        // Late duplicate completion for a recycled slot index: no panic,
        // no state corruption.
        st.complete_extent(a, 0, &view(&[9, 9, 9]), false);
        assert_eq!(st.buffered(), 0);
        // A late ERROR completion for the delivered slot is equally
        // stale: slot index 2 recycles slot 0's ring position, and a
        // late fail(0) must not mark that healthy new occupant Failed.
        let b = st.allocate(2, 16).unwrap();
        let c = st.allocate(3, 16).unwrap();
        assert_eq!(c % 2, a % 2, "c recycles a's ring position");
        st.set_extents(b, &[ext(0, 3)]);
        st.set_extents(c, &[ext(4, 3)]);
        st.fail(a);
        st.complete_extent(b, 0, &view(&[7, 7, 7]), false);
        st.complete_extent(c, 0, &view(&[8, 8, 8]), false);
        st.advance_buffered();
        let (id, status, _) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (2, StagedStatus::Done));
        st.pop_delivered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (3, StagedStatus::Done), "stale fail hit the new occupant");
        assert_eq!(data, vec![8, 8, 8]);
    }

    #[test]
    fn fail_stalled_unblocks_in_order_delivery() {
        let mut st = staging(8);
        let a = st.allocate(1, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        let b = st.allocate(2, crate::proto::FileResponse::HEADER_LEN + 4).unwrap();
        st.set_extents(a, &[ext(0, 4)]);
        st.set_extents(b, &[ext(4, 4)]);
        // b completes; a's completion is lost. Nothing deliverable yet.
        st.complete_extent(b, 0, &view(&[2, 2, 2, 2]), false);
        assert!(st.fail_stalled(Duration::from_secs(60)).is_empty(), "not stalled yet");
        st.advance_buffered();
        assert!(st.peek_deliverable().is_none());
        // Timeout elapses (zero budget): a is aborted, both deliver in
        // order — a as Failed, b as Done — and the aborted slot's index
        // comes back so the caller can roll back keyed resources.
        assert_eq!(st.fail_stalled(Duration::ZERO), vec![a]);
        st.advance_buffered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (1, StagedStatus::Failed));
        assert!(data.is_empty());
        st.pop_delivered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (2, StagedStatus::Done));
        assert_eq!(data, vec![2, 2, 2, 2]);
        // A completed head is never aborted.
        let c = st.allocate(3, crate::proto::FileResponse::HEADER_LEN).unwrap();
        st.set_extents(c, &[ext(8, 4)]);
        st.complete_extent(c, 0, &view(&[]), false);
        assert!(st.fail_stalled(Duration::ZERO).is_empty());
    }

    /// The durable-WRITE gate: a gated slot whose extents all complete
    /// stays Pending (commit-ready) and only `commit_done` — the remap
    /// ack point — delivers it; failure aborts it like any other slot.
    #[test]
    fn gated_slot_delivers_only_after_commit() {
        let mut st = staging(8);
        let a = st.allocate(1, crate::proto::FileResponse::HEADER_LEN).unwrap();
        st.set_extents(a, &[ext(0, 4), ext(512, 4)]);
        st.set_gated(a);
        st.complete_extent(a, 0, &view(&[]), false);
        assert!(!st.commit_ready(a), "one extent still in flight");
        st.complete_extent(a, 1, &view(&[]), false);
        assert!(st.commit_ready(a));
        st.advance_buffered();
        assert!(st.peek_deliverable().is_none(), "no ack before commit");
        st.commit_done(a);
        assert!(!st.commit_ready(a), "commit consumed the gate");
        st.advance_buffered();
        let (id, status, _) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (1, StagedStatus::Done));
        st.pop_delivered();
        // commit_done on a stale (recycled) index is a no-op.
        st.commit_done(a);
        // A gated slot that fails pre-commit delivers Failed: the ack
        // was never sent, so the client sees a clean bounded ERR.
        let b = st.allocate(2, crate::proto::FileResponse::HEADER_LEN).unwrap();
        st.set_extents(b, &[ext(0, 4)]);
        st.set_gated(b);
        st.complete_extent(b, 0, &view(&[]), false);
        assert!(st.commit_ready(b));
        st.fail(b);
        assert!(!st.commit_ready(b));
        st.commit_done(b); // late commit after failure: ignored
        st.advance_buffered();
        let (id, status, _) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (2, StagedStatus::Failed));
    }

    #[test]
    fn write_slot_zero_extents_completes_via_counter() {
        let mut st = staging(2);
        let a = st.allocate(5, crate::proto::FileResponse::HEADER_LEN).unwrap();
        st.set_extents(a, &[ext(0, 8)]);
        st.complete_extent(a, 0, &view(&[]), false); // write completion: no data
        st.advance_buffered();
        let (id, status, data) = st.peek_deliverable().unwrap();
        assert_eq!((id, status), (5, StagedStatus::Done));
        assert!(data.is_empty());
    }
}
