//! The DPU file service (§4.3) — the back end of the unified storage
//! path.
//!
//! One service thread per DPU (the paper dedicates one Arm core):
//!
//! 1. DMA-reads batches of [`FileRequest`]s from each poll group's host
//!    request ring (the progress-ring drain of Fig 8b) — groups are
//!    visited round-robin from a rotating start so a backlogged group
//!    (one notification group per host thread/shard, §4.2) can never
//!    starve the others;
//! 2. translates file addresses through the [`DpuFs`] file mapping and
//!    submits per-extent ops to the SPDK-like [`AsyncSsd`] — pointing
//!    the driver directly at request/response buffer memory (zero-copy,
//!    §4.3);
//! 3. *pre-allocates* response space before submitting each I/O, and
//!    delivers responses **in request order** with the three tail
//!    pointers of §4.3 "Ordered execution": `TailA` (allocated),
//!    `TailB` (buffered/completed), `TailC` (delivered);
//! 4. invokes the user's `Cache`/`Invalidate` hooks on host writes/reads
//!    to keep the DPU cache table fresh (§6.1);
//! 5. DMA-writes completed responses to the host response ring in
//!    batches and fires the group's doorbell (the driver interrupt that
//!    wakes sleeping `PollWait` callers, §4.2).

mod staging;

pub use staging::{OrderedStaging, StagedStatus};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::buf::{BufPool, BufView};
use crate::cache::{CuckooCache, FillTicket, Probe, ReadCacheTier, TierStats};
use crate::dma::DmaChannel;
use crate::dpufs::{DirId, DpuFs, FileId, FsError, RecoveryReport, RedirectPlan};
use crate::idle::IdleGovernor;
use crate::metrics::{
    merge_tenant_tables, CpuLedger, CpuStats, LatencyHistogram, LatencyStats, TenantCounters,
};
use crate::offload::{OffloadLogic, ReadOp, WriteOp};
use crate::proto::{FileOpKind, FileRequest, FileResponse, Status};
use crate::ring::{ProgressRing, ResponseRing};
use crate::ssd::{AsyncSsd, Completion, SsdOp};

// The wake machinery lives in the CPU plane (`crate::idle`);
// re-exported here because the doorbell is part of the poll-group API
// surface (§4.2) and long predates the idle module.
pub use crate::idle::{Doorbell, IdlePolicy};

/// Control-plane operations (§4.2: directory/file management). Rare, so
/// they travel over a channel to the service thread rather than the
/// data-plane rings.
pub enum ControlMsg {
    CreateDirectory { name: String, reply: mpsc::Sender<Result<DirId, FsError>> },
    RemoveDirectory { dir: DirId, reply: mpsc::Sender<Result<(), FsError>> },
    CreateFile { dir: DirId, name: String, reply: mpsc::Sender<Result<FileId, FsError>> },
    DeleteFile { file: FileId, reply: mpsc::Sender<Result<(), FsError>> },
    EnsureSize { file: FileId, size: u64, reply: mpsc::Sender<Result<(), FsError>> },
    FileSize { file: FileId, reply: mpsc::Sender<Result<u64, FsError>> },
    /// Register a poll group's rings with the service.
    CreatePoll { group: Arc<GroupChannel>, reply: mpsc::Sender<usize> },
    /// Per-group service counters (requests drained / responses
    /// delivered / in flight), indexed by group id.
    GroupStats { reply: mpsc::Sender<Vec<GroupCounters>> },
    /// CPU-ledger snapshot of the service pump (the functional Fig 14
    /// CPU axis: iterations, parks, wakes, busy fraction).
    CpuStats { reply: mpsc::Sender<CpuStats> },
    /// Tail-latency summary: the service's own staging-to-delivery
    /// recorder merged with every registered peer recorder (director
    /// shards register theirs via
    /// [`crate::coordinator::StorageServer::register_latency_recorder`]),
    /// so one control round trip reports the whole deployment's
    /// p50/p99/p99.9 trajectory.
    LatencyStats { reply: mpsc::Sender<LatencyStats> },
    /// Per-tenant QoS counters merged across every registered source
    /// (director shards register their tables via
    /// [`crate::coordinator::StorageServer::register_tenant_source`]):
    /// admitted/completed/rejected/throttled per tenant, one control
    /// round trip for the whole deployment's fairness picture.
    TenantStats { reply: mpsc::Sender<Vec<TenantCounters>> },
    /// Fault plane: stall one poll group for N service iterations (the
    /// service neither drains its request ring nor delivers its
    /// responses while stalled). Replies whether the group exists.
    InjectGroupStall { group: usize, iterations: u32, reply: mpsc::Sender<bool> },
    SyncMetadata { reply: mpsc::Sender<Result<(), FsError>> },
    /// Operator surface for mount-time crash recovery: what the last
    /// mount rolled forward/back, replayed, and quarantined. `None`
    /// after a fresh format (no recovery ran).
    RecoveryReport { reply: mpsc::Sender<Option<RecoveryReport>> },
    /// Read-cache tier counters (hits/misses/fills/invalidations/
    /// evictions/bytes_served). All-zero (budget 0) when no tier is
    /// attached.
    CacheStats { reply: mpsc::Sender<TierStats> },
    Shutdown,
}

/// Per-poll-group counters reported by [`ControlMsg::GroupStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GroupCounters {
    /// Requests drained from the group's request ring.
    pub requests: u64,
    /// Responses DMA-written to the group's response ring.
    pub delivered: u64,
    /// Requests accepted but not yet delivered.
    pub outstanding: usize,
    /// Service iterations this group spent fault-stalled.
    pub stalled: u64,
    /// Staging slots aborted by the pending-timeout (lost SSD
    /// completions surfaced as Error responses).
    pub timed_out: u64,
}

/// The shared rings + doorbells of one notification group.
pub struct GroupChannel {
    pub req_ring: ProgressRing,
    pub resp_ring: ResponseRing,
    /// Host-facing doorbell: the service rings it when responses are
    /// DMA-written, waking sleeping `PollWait` callers (§4.2).
    pub doorbell: Arc<Doorbell>,
    /// Service-facing doorbell (the reverse direction of the wake
    /// graph): request-ring pushes ring it so a parked service pump
    /// wakes, and response-ring drains ring it so a delivery blocked
    /// on a full host ring retries as soon as space frees up.
    pub wake: Arc<Doorbell>,
}

/// Service configuration.
#[derive(Clone)]
pub struct FileServiceConfig {
    /// SPDK worker threads (§7).
    pub ssd_workers: usize,
    /// Staging slots per group — must cover the request ring (§4.3: the
    /// DPU request buffer is "the same as or greater than the request
    /// ring size ... so no outstanding requests overlap").
    pub staging_slots: usize,
    /// Deliver responses to the host once this many are buffered
    /// (`TailB - TailC` batch threshold, §4.3).
    pub delivery_batch: usize,
    /// Straw-man extra copies (the Fig 18 ablation): staging copies of
    /// request and response payloads.
    pub extra_copy: bool,
    /// Injected per-DMA-op latency (0 = off).
    pub dma_latency_ns: u64,
    /// How long a staging slot may sit pending before the service gives
    /// up on its SSD completion and delivers an Error response
    /// (lost-completion recovery; in-order delivery would otherwise
    /// wedge the whole group behind one lost interrupt).
    pub pending_timeout: std::time::Duration,
    /// Optional fault injector for the service's SSD queue (the host
    /// slow path's hook point in the fault plane).
    pub ssd_faults: Option<crate::fault::SsdFaultInjector>,
    /// Buffer-pool slots for the service's big size class (request
    /// batch staging + multi-extent assembly).
    pub pool_slots: usize,
    /// Big size class in bytes. Must cover the request ring's max
    /// allowable progress (one slot stages a whole drained batch);
    /// bigger requests fall back to counted heap allocations rather
    /// than failing.
    pub pool_slot_size: usize,
    /// Slots in the read-completion size class. Each in-flight SSD read
    /// pins one slot until its response is delivered, so this bounds
    /// steady-state read queue depth before counted heap fallbacks.
    pub read_pool_slots: usize,
    /// Read-completion size class in bytes (the common read size;
    /// larger reads fall back, counted).
    pub read_pool_slot_size: usize,
    /// Durability policy: run the crash-consistent metadata sync
    /// (journal append → shadow superblock → commit) after every
    /// *control-plane* metadata mutation (create/remove directory,
    /// create/delete file, explicit `EnsureSize`). A mutation whose
    /// sync fails is surfaced to the caller as that error — applied in
    /// memory, but not yet durable. The data-plane write path never
    /// syncs: growth from writes becomes durable at the next
    /// control-plane op or an explicit `SyncMetadata`.
    pub durable_metadata: bool,
    /// Data-path durability (redirect-on-write): WRITEs stage their
    /// payload into freshly allocated shadow extents and the response
    /// is acked only after the extent-remap record is durably
    /// journaled — the ack point moves from "payload landed" to
    /// "commit record appended". A power cut before the ack leaves the
    /// old bytes fully intact (the un-acked WRITE surfaces as a clean
    /// bounded ERR, never a torn extent). Off by default: the in-place
    /// path acks on payload completion, like a volatile write cache.
    pub durable_data: bool,
    /// What the service pump does when an iteration finds no work:
    /// busy-poll (`Poll`, the SPDK discipline — one core even when
    /// idle) or the spin→yield→park ladder (`Adaptive`, the default).
    /// Parks sleep on the service wake doorbell, which request pushes,
    /// control sends, response-ring drains and SSD-worker completions
    /// all ring — and every park is bounded by the policy's
    /// `park_timeout`, so a missed edge costs latency, never a hang.
    pub idle: IdlePolicy,
}

impl Default for FileServiceConfig {
    fn default() -> Self {
        FileServiceConfig {
            // 0 = inline polled mode (SPDK-style); >0 spawns worker
            // threads and yields genuinely out-of-order completions
            // (integration tests set this to stress ordered delivery).
            ssd_workers: 0,
            staging_slots: 4096,
            delivery_batch: 1,
            extra_copy: false,
            dma_latency_ns: 0,
            pending_timeout: std::time::Duration::from_secs(5),
            ssd_faults: None,
            // Two size classes (see DESIGN.md "buffer plane"):
            // 64 × 256 KiB batch/assembly slots (covers the default
            // ring's 256 KiB max progress) + 256 × 64 KiB
            // read-completion slots (256 in-flight reads before
            // fallback, without pinning a batch-class slot per read).
            pool_slots: 64,
            pool_slot_size: 256 << 10,
            read_pool_slots: 256,
            read_pool_slot_size: 64 << 10,
            durable_metadata: true,
            durable_data: false,
            idle: IdlePolicy::default(),
        }
    }
}

struct ServiceGroup {
    chan: Arc<GroupChannel>,
    staging: OrderedStaging,
    /// Requests drained from this group's ring.
    requests: u64,
    /// Responses delivered to this group's ring.
    delivered: u64,
    /// Fault plane: remaining stall iterations (skip intake+delivery).
    stall: u32,
    /// Iterations spent stalled (monotonic).
    stalled: u64,
    /// Slots aborted by the pending-timeout (monotonic).
    timed_out: u64,
}

/// Handle for a spawned service; stops the thread on drop.
pub struct FileServiceHandle {
    ctrl: mpsc::Sender<ControlMsg>,
    join: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    wake: Arc<Doorbell>,
}

impl FileServiceHandle {
    pub fn control(&self) -> mpsc::Sender<ControlMsg> {
        self.ctrl.clone()
    }
}

impl Drop for FileServiceHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.ctrl.send(ControlMsg::Shutdown);
        // The service may be parked: ring it so shutdown is prompt
        // (the stop flag alone is only observed on iteration).
        self.wake.ring();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Deferred work bound to one extent's SSD completion (see
/// [`FileService::completion_actions`]).
enum CompletionAction {
    /// READ miss in flight: fill the tier from the completion's pooled
    /// view under the ticket taken at probe time (the epoch guard
    /// drops the fill if a WRITE invalidated the range in between).
    Fill(FillTicket),
    /// Non-durable WRITE extent: invalidate `(file, offset, len)` when
    /// the payload lands — the completion is the ack point, so cached
    /// pre-overwrite bytes become unreachable no later than the ack.
    Invalidate { file: u64, offset: u64, len: u64 },
}

/// The file service state machine (runs on the service thread; also
/// drivable step-by-step in tests via [`FileService::run_once`]).
pub struct FileService {
    dpufs: Arc<RwLock<DpuFs>>,
    aio: AsyncSsd,
    dma: DmaChannel,
    cfg: FileServiceConfig,
    /// Big size class of the service's zero-copy plane: request-batch
    /// staging + multi-extent assembly. Shares one copy ledger with
    /// `read_pool`, so either pool's `stats()` meters the whole plane.
    pool: BufPool,
    /// Read-completion size class (attached to the SSD queue).
    read_pool: BufPool,
    groups: Vec<ServiceGroup>,
    /// Rotating round-robin starts for request intake and response
    /// delivery (fairness across poll groups).
    rr_intake: usize,
    rr_deliver: usize,
    ctrl_rx: mpsc::Receiver<ControlMsg>,
    logic: Option<Arc<dyn OffloadLogic>>,
    cache: Arc<CuckooCache>,
    /// The service pump's wake doorbell (see [`GroupChannel::wake`]).
    wake: Arc<Doorbell>,
    /// The pump's CPU ledger (iterations / parks / busy fraction).
    cpu: Arc<CpuLedger>,
    /// Service-side latency recorder: staging allocation (request
    /// admitted) → response DMA-written to the host ring. One clock
    /// read meters each delivery burst.
    lat: Arc<LatencyHistogram>,
    /// Peer recorders folded into [`ControlMsg::LatencyStats`] replies
    /// (director shards register theirs through the storage server).
    lat_peers: Arc<Mutex<Vec<Arc<LatencyHistogram>>>>,
    /// Per-shard tenant counter tables folded into
    /// [`ControlMsg::TenantStats`] replies (same registration pattern
    /// as `lat_peers`).
    tenant_peers: Arc<Mutex<Vec<Arc<Mutex<Vec<TenantCounters>>>>>>,
    /// Reused burst buffers — the batch pipeline's steady state
    /// allocates nothing: SSD ops staged per intake burst, completions
    /// polled per absorb pass, deliverables gathered per response burst.
    submit_buf: Vec<(u64, SsdOp)>,
    comp_buf: Vec<Completion>,
    deliver_buf: Vec<([u8; FileResponse::HEADER_LEN], BufView)>,
    /// In-flight durable-WRITE redirect plans, keyed by (group, slot).
    /// Inserted when the shadow writes are submitted; removed at commit
    /// (last completion), or at abort (error completion / stalled-slot
    /// timeout — the shadows go back to the allocator, no ack is sent).
    pending_plans: HashMap<(usize, u64), RedirectPlan>,
    /// The DPU-side read cache tier, if attached (see
    /// [`Self::attach_tier`]). READs probe it before staging SSD ops;
    /// hits complete the staging slot immediately with the cached view
    /// (zero-copy — a refcount bump, no `AsyncSsd` round trip).
    tier: Option<Arc<ReadCacheTier>>,
    /// What to do when an extent's SSD completion lands, keyed by the
    /// completion tag's (group, slot, extent): install a READ's view
    /// under its probe-time ticket, or invalidate a non-durable
    /// WRITE's byte range at its ack point. Purged when a slot fails
    /// or times out (pending WRITE invalidations still run then —
    /// the bytes may have landed without a completion, and a spurious
    /// invalidation is safe where a missed one is a stale read).
    completion_actions: HashMap<(usize, u64, usize), CompletionAction>,
    /// Mount-time recovery report, surfaced via
    /// [`ControlMsg::RecoveryReport`]. `None` on a fresh format.
    recovery: Option<RecoveryReport>,
}

impl FileService {
    /// Build a service; returns `(service, control sender)`.
    pub fn new(
        dpufs: Arc<RwLock<DpuFs>>,
        mut aio: AsyncSsd,
        cfg: FileServiceConfig,
        logic: Option<Arc<dyn OffloadLogic>>,
        cache: Arc<CuckooCache>,
    ) -> (Self, mpsc::Sender<ControlMsg>) {
        if let Some(inj) = cfg.ssd_faults.clone() {
            aio.attach_faults(inj);
        }
        // One ledger across both size classes: the copy meter sees the
        // whole service plane no matter which pool served a request.
        let ledger = crate::buf::CopyLedger::new();
        let pool = BufPool::with_ledger(cfg.pool_slots, cfg.pool_slot_size, ledger.clone());
        let read_pool =
            BufPool::with_ledger(cfg.read_pool_slots, cfg.read_pool_slot_size, ledger);
        // SSD read completions land in the read-class pool (§4.3: the
        // driver DMAs into pre-allocated response memory) — sized for
        // the common read, so a 4 KiB completion never pins a 256 KiB
        // batch slot.
        aio.attach_read_pool(read_pool.clone());
        let wake = Doorbell::new();
        // Worker-mode SSD completions are posted by worker threads
        // while the service may be parked — they ring it awake.
        aio.attach_waker(wake.clone());
        let cpu = CpuLedger::new();
        let (tx, rx) = mpsc::channel();
        let dma = if cfg.dma_latency_ns > 0 {
            DmaChannel::with_latency(cfg.dma_latency_ns)
        } else {
            DmaChannel::new()
        };
        (
            FileService {
                dpufs,
                aio,
                dma,
                cfg,
                pool,
                read_pool,
                groups: Vec::new(),
                rr_intake: 0,
                rr_deliver: 0,
                ctrl_rx: rx,
                logic,
                cache,
                wake,
                cpu,
                lat: LatencyHistogram::new(),
                lat_peers: Arc::new(Mutex::new(Vec::new())),
                tenant_peers: Arc::new(Mutex::new(Vec::new())),
                submit_buf: Vec::new(),
                comp_buf: Vec::new(),
                deliver_buf: Vec::new(),
                pending_plans: HashMap::new(),
                recovery: None,
                tier: None,
                completion_actions: HashMap::new(),
            },
            tx,
        )
    }

    /// Attach the mount-time [`RecoveryReport`] (call before `spawn`;
    /// the coordinator plumbs it from `StorageServer::remount`).
    pub fn set_recovery_report(&mut self, report: RecoveryReport) {
        self.recovery = Some(report);
    }

    /// Attach the DPU-side read cache tier (call before `spawn`). The
    /// same `Arc` should be attached to every colocated offload engine
    /// and registered as the DpuFs remap-commit hook — DPU memory is
    /// one resource, so there is one tier per server.
    pub fn attach_tier(&mut self, tier: Arc<ReadCacheTier>) {
        self.tier = Some(tier);
    }

    /// Spawn the service thread (pump discipline set by
    /// [`FileServiceConfig::idle`]).
    pub fn spawn(mut self, ctrl: mpsc::Sender<ControlMsg>) -> FileServiceHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let wake = self.wake.clone();
        let join = std::thread::Builder::new()
            .name("dds-file-service".into())
            .spawn(move || {
                let mut gov = IdleGovernor::new(self.cfg.idle, self.cpu.clone());
                loop {
                    // Snapshot the doorbell BEFORE scanning for work:
                    // a producer that publishes after the scan has
                    // necessarily rung past this sequence, so the park
                    // below returns immediately — the wakeup can be
                    // late (bounded by the backoff) but never lost.
                    let seen = self.wake.seq();
                    let progressed = self.run_once();
                    gov.iteration(progressed);
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    if !progressed {
                        if self.staging_unresolved() {
                            // Staging slots are waiting on completions
                            // with no ring edge into this pump: a
                            // fault-DELAYED completion ages only per
                            // poll, and a DROPPED one resolves only
                            // when fail_stalled sees the pending
                            // timeout elapse. Nap (bounded, short) so
                            // those clocks keep ticking at poll
                            // cadence — a full park would stretch
                            // them by up to park_timeout per tick
                            // (the shard loop's in_flight guard, same
                            // reasoning).
                            gov.idle_nap();
                        } else {
                            gov.idle(&self.wake, seen);
                        }
                    }
                }
            })
            .expect("spawn file service");
        FileServiceHandle { ctrl, join: Some(join), stop, wake }
    }

    /// Any staging slot still waiting on its SSD completion? While
    /// true the pump must keep polling (nap, not park): the completion
    /// may be fault-delayed (ages per poll) or dropped (resolved only
    /// by `fail_stalled` observing the pending timeout) — neither can
    /// ring the doorbell. Completed-but-undelivered slots do NOT need
    /// this guard: sub-threshold batches flush as soon as nothing is
    /// outstanding (see `deliver_responses`), and delivery blocked on
    /// a full host ring is rung awake by the host's drain. Goes back
    /// to 0 once every slot completes or aborts, so an idle service
    /// always reaches the park rung.
    fn staging_unresolved(&self) -> bool {
        self.groups.iter().any(|g| g.staging.outstanding() > 0)
    }

    /// One service iteration: control plane, request intake, completion
    /// processing, response delivery. Returns whether any work was done.
    pub fn run_once(&mut self) -> bool {
        let mut progressed = false;
        progressed |= self.drain_control();
        progressed |= self.intake_requests();
        progressed |= self.absorb_completions();
        progressed |= self.deliver_responses();
        progressed
    }

    fn drain_control(&mut self) -> bool {
        let mut did = false;
        while let Ok(msg) = self.ctrl_rx.try_recv() {
            did = true;
            match msg {
                ControlMsg::CreateDirectory { name, reply } => {
                    let r = self.mutate(|fs| fs.create_directory(&name));
                    let _ = reply.send(r);
                }
                ControlMsg::RemoveDirectory { dir, reply } => {
                    let r = self.mutate(|fs| fs.remove_directory(dir));
                    let _ = reply.send(r);
                }
                ControlMsg::CreateFile { dir, name, reply } => {
                    let r = self.mutate(|fs| fs.create_file(dir, &name));
                    let _ = reply.send(r);
                }
                ControlMsg::DeleteFile { file, reply } => {
                    let r = self.mutate(|fs| fs.delete_file(file));
                    let _ = reply.send(r);
                }
                ControlMsg::EnsureSize { file, size, reply } => {
                    let r = self.mutate(|fs| fs.ensure_size(file, size));
                    let _ = reply.send(r);
                }
                ControlMsg::FileSize { file, reply } => {
                    let r = self.dpufs.read().unwrap().file_meta(file).map(|m| m.size);
                    let _ = reply.send(r);
                }
                ControlMsg::CreatePoll { group, reply } => {
                    let slots = self.cfg.staging_slots;
                    self.groups.push(ServiceGroup {
                        chan: group,
                        staging: OrderedStaging::new(slots, self.pool.clone()),
                        requests: 0,
                        delivered: 0,
                        stall: 0,
                        stalled: 0,
                        timed_out: 0,
                    });
                    let _ = reply.send(self.groups.len() - 1);
                }
                ControlMsg::GroupStats { reply } => {
                    let stats = self
                        .groups
                        .iter()
                        .map(|g| GroupCounters {
                            requests: g.requests,
                            delivered: g.delivered,
                            outstanding: g.staging.outstanding(),
                            stalled: g.stalled,
                            timed_out: g.timed_out,
                        })
                        .collect();
                    let _ = reply.send(stats);
                }
                ControlMsg::CpuStats { reply } => {
                    let _ = reply.send(self.cpu.snapshot());
                }
                ControlMsg::LatencyStats { reply } => {
                    let mut merged = self.lat.snapshot();
                    for peer in self.lat_peers.lock().unwrap().iter() {
                        merged.merge(&peer.snapshot());
                    }
                    let _ = reply.send(merged.stats());
                }
                ControlMsg::TenantStats { reply } => {
                    let tables: Vec<Vec<TenantCounters>> = self
                        .tenant_peers
                        .lock()
                        .unwrap()
                        .iter()
                        .map(|t| t.lock().unwrap().clone())
                        .collect();
                    let _ = reply.send(merge_tenant_tables(&tables));
                }
                ControlMsg::InjectGroupStall { group, iterations, reply } => {
                    let known = group < self.groups.len();
                    if known {
                        self.groups[group].stall = iterations;
                    }
                    let _ = reply.send(known);
                }
                ControlMsg::SyncMetadata { reply } => {
                    let r = self.dpufs.write().unwrap().sync_metadata();
                    let _ = reply.send(r);
                }
                ControlMsg::RecoveryReport { reply } => {
                    let _ = reply.send(self.recovery.clone());
                }
                ControlMsg::CacheStats { reply } => {
                    let stats =
                        self.tier.as_ref().map(|t| t.stats()).unwrap_or_default();
                    let _ = reply.send(stats);
                }
                ControlMsg::Shutdown => {}
            }
        }
        did
    }

    /// Run a control-plane metadata mutation under the durability
    /// policy: apply + sync (journal → superblock → commit), or
    /// neither. If the sync fails — a dead device after a power cut, or
    /// an image grown past the superblock slot's capacity — the
    /// in-memory mutation is ROLLED BACK before the error surfaces, so
    /// a refused op can never be silently persisted by a later op's
    /// successful sync.
    fn mutate<T>(
        &self,
        op: impl FnOnce(&mut DpuFs) -> Result<T, FsError>,
    ) -> Result<T, FsError> {
        let mut fs = self.dpufs.write().unwrap();
        if !self.cfg.durable_metadata {
            return op(&mut fs);
        }
        let snapshot = fs.meta_snapshot();
        match op(&mut fs) {
            Ok(v) => {
                if let Err(e) = fs.sync_metadata() {
                    fs.restore_snapshot(snapshot);
                    return Err(e);
                }
                Ok(v)
            }
            Err(e) => {
                // DpuFs ops are atomic-on-failure themselves; restoring
                // anyway makes "apply + sync, or neither" independent of
                // that property staying true for every future op.
                fs.restore_snapshot(snapshot);
                Err(e)
            }
        }
    }

    /// Drain request rings; submit I/O with pre-allocated responses.
    /// Groups are visited round-robin from a rotating start so the
    /// service divides its drain bandwidth fairly across poll groups.
    fn intake_requests(&mut self) -> bool {
        let n = self.groups.len();
        if n == 0 {
            return false;
        }
        let start = self.rr_intake % n;
        self.rr_intake = self.rr_intake.wrapping_add(1);
        let mut any = false;
        for k in 0..n {
            let gi = (start + k) % n;
            // Fault plane: a stalled group is skipped wholesale — its
            // request ring backs up and its responses sit buffered until
            // the stall budget runs out. The budget is decremented by
            // the delivery pass (which runs after intake), so both
            // passes skip the group for exactly `stall` iterations.
            if self.groups[gi].stall > 0 {
                continue;
            }
            // Don't drain more than staging can absorb (preserves the
            // §4.3 no-overlap invariant).
            if self.groups[gi].staging.free_slots() < 64 {
                continue;
            }
            let mut batch: Vec<FileRequest> = Vec::new();
            let extra_copy = self.cfg.extra_copy;
            {
                let g = &self.groups[gi];
                let pool = &self.pool;
                // The one DMA read of the batch lands in a pooled
                // buffer; each record is decoded as a view of it, so a
                // write's payload is never copied out of the batch.
                g.chan.req_ring.pop_batch_views_dma(&self.dma, pool, &mut |view| {
                    if extra_copy {
                        // Straw-man: stage the request before parsing
                        // (the copy §4.3 eliminates — metered).
                        let staged = BufView::copy_of(pool, view.as_slice());
                        if let Some(req) = FileRequest::decode_view(&staged) {
                            batch.push(req);
                        }
                    } else if let Some(req) = FileRequest::decode_view(&view) {
                        batch.push(req);
                    }
                });
            }
            if batch.is_empty() {
                continue;
            }
            any = true;
            self.groups[gi].requests += batch.len() as u64;
            for req in batch {
                self.execute_request(gi, req);
            }
            // Flush the whole burst's per-extent ops to the SSD queue
            // as ONE submission: one fault-plane pass (submit order
            // preserved), one channel send, and — in worker mode — one
            // completion-lock acquisition + one doorbell ring for the
            // burst's completions instead of per op. The buffer's
            // capacity survives the drain for the next burst.
            if !self.submit_buf.is_empty() {
                let mut ops = std::mem::take(&mut self.submit_buf);
                self.aio.submit_batch(&mut ops);
                self.submit_buf = ops;
            }
        }
        any
    }

    fn execute_request(&mut self, gi: usize, req: FileRequest) {
        let expected = req.expected_response_len();
        // §4.3: pre-allocate the response (TailA advance) BEFORE
        // submitting the I/O; status starts as pending.
        let slot = self.groups[gi]
            .staging
            .allocate(req.req_id, expected)
            .expect("staging sized to cover the request ring");
        let file = FileId(req.file_id);
        match req.kind {
            FileOpKind::Read => {
                // Invalidate-on-read (§6.1).
                if let Some(logic) = &self.logic {
                    let op = ReadOp { file_id: file, offset: req.offset, size: req.size };
                    for key in logic.invalidate(&op) {
                        self.cache.remove(key);
                    }
                }
                let extents = {
                    let fs = self.dpufs.read().unwrap();
                    fs.map_extents(file, req.offset, req.size as u64)
                };
                match extents {
                    Ok(extents) => {
                        self.groups[gi].staging.set_extents(slot, &extents);
                        // Probe the read-cache tier per logical extent
                        // BEFORE staging an SSD op: a hit completes the
                        // staging slot with the cached view right here
                        // (a refcount bump — no copy, no alloc, no SSD
                        // round trip); a miss arms a fill ticket so the
                        // eventual completion warms the tier.
                        let mut log_off = req.offset;
                        for (ei, e) in extents.iter().enumerate() {
                            let ext_off = log_off;
                            log_off += e.len;
                            if let Some(tier) = &self.tier {
                                match tier.probe(req.file_id as u64, ext_off, e.len) {
                                    Probe::Hit(view) => {
                                        self.groups[gi].staging.complete_extent(
                                            slot,
                                            ei,
                                            &view,
                                            self.cfg.extra_copy,
                                        );
                                        continue;
                                    }
                                    Probe::Miss(ticket) => {
                                        self.completion_actions.insert(
                                            (gi, slot, ei),
                                            CompletionAction::Fill(ticket),
                                        );
                                    }
                                }
                            }
                            let tag = pack_tag(gi, slot, ei);
                            self.submit_buf
                                .push((tag, SsdOp::Read { addr: e.addr, len: e.len as usize }));
                        }
                    }
                    Err(_) => self.groups[gi].staging.fail(slot),
                }
            }
            FileOpKind::Write => {
                // Cache-on-write (§6.1).
                if let Some(logic) = &self.logic {
                    let op = WriteOp { file_id: file, offset: req.offset, data: &req.data };
                    for (key, item) in logic.cache(&op) {
                        self.cache.insert(key, item);
                    }
                }
                if self.cfg.durable_data {
                    // Redirect-on-write durable path: the payload goes
                    // to shadow extents and the response is gated on
                    // the remap commit (run by `absorb_completions`
                    // when the last shadow write lands). Growth is the
                    // plan's job, so no `ensure_size` here.
                    let plan = {
                        let mut fs = self.dpufs.write().unwrap();
                        fs.redirect_prepare(file, req.offset, req.data.len() as u64)
                    };
                    match plan {
                        Ok(plan) if plan.extents.is_empty() => {
                            // Zero-length WRITE: nothing to stage —
                            // commit the (trivial) plan synchronously,
                            // then let the empty extent list complete
                            // the slot.
                            let r = self.dpufs.write().unwrap().redirect_commit(plan);
                            match r {
                                Ok(()) => self.groups[gi].staging.set_extents(slot, &[]),
                                Err(_) => self.groups[gi].staging.fail(slot),
                            }
                        }
                        Ok(plan) => {
                            self.groups[gi].staging.set_extents(slot, &plan.extents);
                            self.groups[gi].staging.set_gated(slot);
                            let mut at = 0usize;
                            for (ei, e) in plan.extents.iter().enumerate() {
                                let tag = pack_tag(gi, slot, ei);
                                let chunk = req.data.slice(at..at + e.len as usize);
                                at += e.len as usize;
                                self.submit_buf
                                    .push((tag, SsdOp::Write { addr: e.addr, data: chunk }));
                            }
                            self.pending_plans.insert((gi, slot), plan);
                        }
                        Err(_) => self.groups[gi].staging.fail(slot),
                    }
                    return;
                }
                // Allocation may be needed: take the write lock briefly.
                let extents = {
                    let mut fs = self.dpufs.write().unwrap();
                    fs.ensure_size(file, req.offset + req.data.len() as u64)
                        .and_then(|_| fs.map_extents(file, req.offset, req.data.len() as u64))
                };
                match extents {
                    Ok(extents) => {
                        self.groups[gi].staging.set_extents(slot, &extents);
                        let mut at = 0usize;
                        let mut log_off = req.offset;
                        for (ei, e) in extents.iter().enumerate() {
                            let tag = pack_tag(gi, slot, ei);
                            // Zero-copy contract: each per-extent chunk
                            // is a sub-view of the request payload (which
                            // itself aliases the DMA'd batch buffer) —
                            // the driver consumes it by reference; the
                            // straw-man's extra copy is modeled at
                            // intake.
                            let chunk = req.data.slice(at..at + e.len as usize);
                            at += e.len as usize;
                            // Cache coherence: invalidate at the ack
                            // point (this extent's completion), not at
                            // submit — invalidating now would let a
                            // racing READ that the SSD reorders ahead
                            // of this write re-fill the tier with
                            // pre-overwrite bytes under a post-
                            // invalidation ticket.
                            if self.tier.is_some() {
                                self.completion_actions.insert(
                                    (gi, slot, ei),
                                    CompletionAction::Invalidate {
                                        file: req.file_id as u64,
                                        offset: log_off,
                                        len: e.len,
                                    },
                                );
                            }
                            log_off += e.len;
                            self.submit_buf
                                .push((tag, SsdOp::Write { addr: e.addr, data: chunk }));
                        }
                    }
                    Err(_) => self.groups[gi].staging.fail(slot),
                }
            }
        }
    }

    /// Poll SSD completions into staging slots (TailB candidates).
    /// Polls into the reused completion buffer — an idle pass costs a
    /// relaxed load, not an allocation or a lock.
    fn absorb_completions(&mut self) -> bool {
        let mut completions = std::mem::take(&mut self.comp_buf);
        let any = self.aio.poll_into(&mut completions, 1 << 12) > 0;
        for c in completions.drain(..) {
            let (gi, slot, extent) = unpack_tag(c.tag);
            if gi >= self.groups.len() {
                continue;
            }
            if c.result.is_err() {
                self.groups[gi].staging.fail(slot);
                // A failed shadow write aborts the gated WRITE's plan:
                // the shadows go back to the allocator, no commit runs,
                // and the client gets ERR with the old bytes intact.
                if let Some(plan) = self.pending_plans.remove(&(gi, slot)) {
                    self.dpufs.write().unwrap().redirect_abort(&plan);
                }
                self.purge_actions(gi, slot);
            } else {
                match self.completion_actions.remove(&(gi, slot, extent)) {
                    Some(CompletionAction::Fill(ticket)) => {
                        // Warm the tier from the already-pooled read
                        // view; the ticket's epoch guard drops the
                        // fill if a WRITE invalidated the range while
                        // this read was in flight.
                        if let Some(tier) = &self.tier {
                            tier.fill(&ticket, &c.data);
                        }
                    }
                    Some(CompletionAction::Invalidate { file, offset, len }) => {
                        // Non-durable WRITE ack point: the payload is
                        // on the device, cached pre-overwrite bytes
                        // must become unreachable before the client
                        // sees the ack.
                        if let Some(tier) = &self.tier {
                            tier.invalidate(file, offset, len);
                        }
                    }
                    None => {}
                }
                let staging = &mut self.groups[gi].staging;
                staging.complete_extent(slot, extent, &c.data, self.cfg.extra_copy);
                if staging.commit_ready(slot) {
                    // Last shadow write landed: run the commit — the
                    // remap journal append IS the ack point. Failure
                    // surfaces as a clean ERR (the plan's shadows are
                    // already rolled back by `redirect_commit`).
                    let plan = self
                        .pending_plans
                        .remove(&(gi, slot))
                        .expect("commit-ready slot has a stashed plan");
                    let r = self.dpufs.write().unwrap().redirect_commit(plan);
                    let staging = &mut self.groups[gi].staging;
                    match r {
                        Ok(()) => staging.commit_done(slot),
                        Err(_) => staging.fail(slot),
                    }
                }
            }
        }
        self.comp_buf = completions;
        any
    }

    /// Drop a failed/timed-out slot's pending completion actions. Fill
    /// tickets are simply discarded (a late completion then finds no
    /// ticket and cannot fill), but pending WRITE invalidations RUN:
    /// a lost completion doesn't mean the payload missed the device,
    /// and over-invalidating is safe where under-invalidating is a
    /// stale read.
    fn purge_actions(&mut self, gi: usize, slot: u64) {
        let tier = self.tier.clone();
        self.completion_actions.retain(|&(g, s, _), action| {
            if g != gi || s != slot {
                return true;
            }
            if let (Some(t), CompletionAction::Invalidate { file, offset, len }) =
                (&tier, &*action)
            {
                t.invalidate(*file, *offset, *len);
            }
            false
        });
    }

    /// Advance TailB over completed slots; once the batch threshold is
    /// reached, DMA-write responses to the host ring (TailC advance) and
    /// ring the group's doorbell. Round-robined like intake so one
    /// group's full response ring can't delay everyone else's doorbell.
    ///
    /// Delivery is burst-vectored: the whole deliverable window is
    /// gathered (payloads ride as [`BufView`] clones — refcounts, not
    /// copies) and handed to the host ring as ONE push sequence — a
    /// single batched DMA write, a single tail publish, and one
    /// doorbell ring per group burst.
    fn deliver_responses(&mut self) -> bool {
        let n = self.groups.len();
        if n == 0 {
            return false;
        }
        let start = self.rr_deliver % n;
        self.rr_deliver = self.rr_deliver.wrapping_add(1);
        let pending_timeout = self.cfg.pending_timeout;
        let mut burst = std::mem::take(&mut self.deliver_buf);
        let mut any = false;
        for k in 0..n {
            let gi = (start + k) % n;
            let g = &mut self.groups[gi];
            if g.stall > 0 {
                // Last pass of this service iteration: consume one
                // stall tick (intake already skipped on the same tick).
                g.stall -= 1;
                g.stalled += 1;
                // Serving a stall tick IS progress: the fault plane
                // denominates stalls in service iterations, so the
                // pump must keep iterating (not park) to burn the
                // budget at the cadence the scenarios were written for.
                any = true;
                continue;
            }
            // Lost-completion recovery: abort slots stuck pending past
            // the timeout so one lost interrupt can't wedge the group's
            // in-order delivery forever. Aborted durable WRITEs also
            // roll back their redirect plans — the un-acked shadows go
            // home and the ERR response carries no durability claim.
            let stalled = g.staging.fail_stalled(pending_timeout);
            g.timed_out += stalled.len() as u64;
            for slot in stalled {
                if let Some(plan) = self.pending_plans.remove(&(gi, slot)) {
                    self.dpufs.write().unwrap().redirect_abort(&plan);
                }
                self.purge_actions(gi, slot);
            }
            let g = &mut self.groups[gi];
            g.staging.advance_buffered();
            // Deliver on the batch threshold — OR as soon as the group
            // has nothing in flight that could still grow the batch. A
            // sub-threshold batch with outstanding() == 0 would
            // otherwise sit buffered until unrelated future requests
            // pushed it over the line (with delivery_batch > 1, a
            // client that issued a non-multiple and went quiet would
            // never see its tail responses).
            let buffered = g.staging.buffered();
            if buffered == 0
                || (buffered < self.cfg.delivery_batch && g.staging.outstanding() > 0)
            {
                continue;
            }
            // Gather the deliverable window: each record is a vectored
            // (header, payload-view) pair — §4.3's scatter-gather DMA
            // with no concatenation buffer (the pre-allocated read
            // buffer IS the response payload).
            burst.clear();
            while let Some((req_id, status, data)) = g.staging.peek_deliverable_at(burst.len()) {
                let code = if status == StagedStatus::Done { Status::Ok } else { Status::Error };
                burst.push((FileResponse::encode_header(req_id, code, data.len()), data));
            }
            let pushed = g.chan.resp_ring.push_burst_vectored_dma(
                &self.dma,
                burst.iter().map(|(h, d)| [&h[..], d.as_slice()]),
            );
            // A partial push means the host ring filled mid-burst; the
            // rest stays staged and retries when the host's drain rings
            // the service awake.
            if pushed > 0 {
                // One clock read meters the whole burst's service
                // latency (allocation → DMA-written).
                let now = Instant::now();
                for _ in 0..pushed {
                    let issued = g.staging.pop_delivered();
                    self.lat.record_duration(now.duration_since(issued));
                }
                g.delivered += pushed as u64;
                g.chan.doorbell.ring();
                any = true;
            }
            burst.clear(); // release the payload refcounts promptly
        }
        self.deliver_buf = burst;
        any
    }

    /// DMA statistics (reads, writes).
    pub fn dma_stats(&self) -> (u64, u64) {
        (self.dma.reads(), self.dma.writes())
    }

    /// The service's batch/assembly pool (clone the handle before
    /// `spawn` to observe occupancy and the — shared — copy ledger
    /// from outside the service thread).
    pub fn buf_pool(&self) -> &BufPool {
        &self.pool
    }

    /// The service's read-completion pool.
    pub fn read_buf_pool(&self) -> &BufPool {
        &self.read_pool
    }

    /// The service pump's wake doorbell. Clone before `spawn`:
    /// producers outside the built-in wake graph (request pushes,
    /// control sends, drains, SSD workers) can ring a parked service
    /// awake through it.
    pub fn waker(&self) -> Arc<Doorbell> {
        self.wake.clone()
    }

    /// The service pump's CPU ledger. Clone before `spawn` to observe
    /// busy fraction / parks / wakes without a control round trip.
    pub fn cpu_ledger(&self) -> Arc<CpuLedger> {
        self.cpu.clone()
    }

    /// The service's own latency recorder (staging allocation →
    /// response delivered). Clone before `spawn` to observe without a
    /// control round trip.
    pub fn latency_recorder(&self) -> Arc<LatencyHistogram> {
        self.lat.clone()
    }

    /// The peer-recorder registry behind [`ControlMsg::LatencyStats`].
    /// Clone before `spawn`; pushing a recorder (a director shard's,
    /// say) folds it into every subsequent control-plane latency reply.
    pub fn latency_peers(&self) -> Arc<Mutex<Vec<Arc<LatencyHistogram>>>> {
        self.lat_peers.clone()
    }

    /// The tenant-table registry behind [`ControlMsg::TenantStats`].
    /// Clone before `spawn`; pushing a per-shard table folds it into
    /// every subsequent control-plane tenant reply.
    pub fn tenant_peers(&self) -> Arc<Mutex<Vec<Arc<Mutex<Vec<TenantCounters>>>>>> {
        self.tenant_peers.clone()
    }
}

#[inline]
fn pack_tag(group: usize, slot: u64, extent: usize) -> u64 {
    (group as u64) << 56 | (slot & 0xff_ffff_ffff) << 16 | extent as u64
}

#[inline]
fn unpack_tag(tag: u64) -> (usize, u64, usize) {
    ((tag >> 56) as usize, (tag >> 16) & 0xff_ffff_ffff, (tag & 0xffff) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_roundtrip() {
        for (g, s, e) in [(0usize, 0u64, 0usize), (3, 12345, 7), (255, 1 << 39, 65535)] {
            assert_eq!(unpack_tag(pack_tag(g, s, e)), (g, s, e));
        }
    }

    // The doorbell's unit tests (wake, timeout, boundary-race verdict)
    // moved with it to `crate::idle`.
}
