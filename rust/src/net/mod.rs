//! Transport simulation (§5).
//!
//! A reliable, sequenced, TCP-like byte stream between two endpoints,
//! with exactly the machinery the paper's network path interacts with:
//! cumulative ACKs, out-of-order buffering, duplicate-ACK fast
//! retransmit (Fig 11), and MSS segmentation.
//!
//! The traffic director uses these endpoints to implement the
//! performance-enhancing proxy (§5.2): instead of letting client
//! segments through to the host (which breaks the host's sequence space
//! when the DPU consumes some of them — the Fig 11 pathology), the PEP
//! *terminates* the client connection on the DPU and re-originates a
//! second connection to the host.

pub mod tcp;

pub use tcp::{Segment, TcpEndpoint};

/// Transport protocol selector in signatures/tuples.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Proto {
    Tcp,
    Udp,
}

/// A flow 5-tuple (§5.1: application signatures filter on it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FiveTuple {
    pub client_ip: u32,
    pub client_port: u16,
    pub server_ip: u32,
    pub server_port: u16,
    pub proto: Proto,
}

impl FiveTuple {
    pub fn new(client_ip: u32, client_port: u16, server_ip: u32, server_port: u16) -> Self {
        FiveTuple { client_ip, client_port, server_ip, server_port, proto: Proto::Tcp }
    }

    /// Tenant identity of this flow for the multi-tenant QoS plane:
    /// tenancy follows the client address (each tenant owns a client
    /// host; its connections differ only by port). `tenants == 0`
    /// collapses everything into tenant 0 (single-tenant deployments
    /// pay nothing); otherwise the address is folded into `tenants`
    /// buckets so synthetic workloads can dial tenant count directly.
    pub fn tenant(&self, tenants: u32) -> u32 {
        if tenants <= 1 {
            0
        } else {
            self.client_ip % tenants
        }
    }
}
