//! Minimal sequenced reliable stream with duplicate-ACK fast retransmit.
//!
//! Models the TCP behaviours the paper's design interacts with (§5.2,
//! Fig 11): byte sequence numbers, cumulative ACKs, out-of-order
//! segment buffering, and the 3-dup-ACK fast-retransmit rule that makes
//! naive partial offloading pathological — when the DPU consumes
//! segments mid-stream, the host receiver sees a sequence gap, duplicate
//! ACKs pile up, and the client retransmits everything the DPU already
//! handled.

use std::collections::BTreeMap;

use crate::buf::{BufView, ByteRope, CopyLedger};

/// Maximum segment size (payload bytes per segment).
pub const MSS: usize = 1460;

/// Receive window: how far past `rcv_nxt` the receiver will buffer
/// out-of-order data. Segments wholly or partly beyond this bound are
/// not buffered (they are re-ACKed and the sender retransmits once the
/// window opens). This caps per-flow reassembly memory — at 10k flows
/// one adversarial sender must not be able to hold unbounded buffers.
pub const RCV_WND: u64 = 1 << 20;

/// A TCP-like segment. `seq`/`payload` carry data; `ack` is cumulative.
///
/// The payload is a refcounted [`BufView`]: segments, the retransmit
/// queue, and out-of-order buffers all reference ONE underlying buffer
/// — cloning a segment (e.g. wire-chaos duplication) bumps a refcount
/// instead of duplicating bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    pub seq: u64,
    pub payload: BufView,
    pub ack: u64,
}

impl Segment {
    pub fn is_pure_ack(&self) -> bool {
        self.payload.is_empty()
    }

    /// Exclusive end of this segment's sequence range.
    pub fn seq_end(&self) -> u64 {
        self.seq + self.payload.len() as u64
    }
}

/// One side of a connection.
#[derive(Debug)]
pub struct TcpEndpoint {
    /// Next sequence number to assign to new data.
    snd_nxt: u64,
    /// Oldest unacknowledged byte.
    snd_una: u64,
    /// Unacked outgoing segments, keyed by seq (retransmit queue).
    /// Views, not clones: each entry references the send buffer.
    unacked: BTreeMap<u64, BufView>,
    /// Next expected incoming byte.
    rcv_nxt: u64,
    /// Out-of-order incoming segments (views into arriving payloads).
    ooo: BTreeMap<u64, BufView>,
    /// In-order payload views ready for the application.
    deliverable: ByteRope,
    /// Duplicate-ACK counter (for fast retransmit).
    dup_acks: u32,
    /// Copy ledger for this endpoint. Its copy points: send-side
    /// staging (`send(&[u8])`), explicit delivery materialization
    /// (`deliver()`), and — metered at the call site — the receive-side
    /// reassembly copy when a delivered rope is absorbed into a
    /// `StreamBuf` (`framing::StreamBuf::extend_rope`).
    ledger: CopyLedger,
    /// Stats: segments retransmitted (the Fig 11 pathology metric).
    pub retransmitted_segments: u64,
    /// Stats: duplicate ACKs sent by our receiver side.
    pub dup_acks_sent: u64,
    /// Stats: ACKs for bytes we never sent (corrupted/forged on the
    /// wire), clamped to `snd_nxt` instead of advancing `snd_una` past
    /// it.
    pub bad_acks: u64,
    /// Stats: out-of-order segments refused because they extend past
    /// the [`RCV_WND`] receive window.
    pub ooo_window_drops: u64,
}

impl Default for TcpEndpoint {
    fn default() -> Self {
        Self::new()
    }
}

impl TcpEndpoint {
    pub fn new() -> Self {
        TcpEndpoint {
            snd_nxt: 0,
            snd_una: 0,
            unacked: BTreeMap::new(),
            rcv_nxt: 0,
            ooo: BTreeMap::new(),
            deliverable: ByteRope::new(),
            dup_acks: 0,
            ledger: CopyLedger::new(),
            retransmitted_segments: 0,
            dup_acks_sent: 0,
            bad_acks: 0,
            ooo_window_drops: 0,
        }
    }

    /// This endpoint's copy ledger.
    pub fn ledger(&self) -> &CopyLedger {
        &self.ledger
    }

    /// Queue application data; returns the segments to put on the wire.
    ///
    /// The borrowed bytes are staged into ONE owned buffer (counted on
    /// the ledger); every segment and the retransmit queue hold views
    /// into it. The old path materialized each MSS chunk twice — once
    /// for the wire segment and once for `unacked`.
    pub fn send(&mut self, data: &[u8]) -> Vec<Segment> {
        if data.is_empty() {
            return Vec::new();
        }
        self.ledger.count_heap_alloc();
        self.ledger.count_copy(data.len());
        self.send_view(BufView::from_vec(data.to_vec()))
    }

    /// Queue an already-buffered payload: zero copies, zero allocations
    /// — segments and the retransmit queue reference `data`.
    pub fn send_view(&mut self, data: BufView) -> Vec<Segment> {
        let mut out = Vec::new();
        let mut at = 0usize;
        while at < data.len() {
            let end = (at + MSS).min(data.len());
            let chunk = data.slice(at..end);
            self.unacked.insert(self.snd_nxt, chunk.clone());
            out.push(Segment { seq: self.snd_nxt, payload: chunk, ack: self.rcv_nxt });
            self.snd_nxt += (end - at) as u64;
            at = end;
        }
        out
    }

    /// Threshold below which rope parts are coalesced by copy instead
    /// of becoming their own segments: a run of small parts (frame
    /// headers, tiny KV payloads) packs MSS-tight, because copying tens
    /// of bytes is far cheaper than per-segment + per-ACK overhead.
    pub const COALESCE_MAX: usize = 512;

    /// Queue a view rope (e.g. response header views interleaved with
    /// pooled payload views). Parts above [`Self::COALESCE_MAX`] are
    /// referenced as-is — zero copies for bulk read payloads; runs of
    /// smaller parts are coalesced into MSS-packed staging buffers
    /// (ledger-counted). A boundary between a small run and a large
    /// part may end a segment early, which is valid TCP (segments are
    /// just byte ranges).
    ///
    /// Deliberate trade-off: a header run directly preceding a bulk
    /// payload is NOT packed into the payload's first MSS — that would
    /// cost an `MSS - header` memcpy per response (~1.4 KiB for a 4 KiB
    /// read) to save one tiny segment, and copies are the metric this
    /// plane minimizes. Bulk responses therefore carry one small header
    /// segment each; all-small workloads (KV) coalesce fully.
    pub fn send_rope(&mut self, rope: ByteRope) -> Vec<Segment> {
        let mut out = Vec::new();
        let mut small: Vec<u8> = Vec::new();
        for part in rope.parts() {
            if part.len() <= Self::COALESCE_MAX {
                if small.is_empty() {
                    self.ledger.count_heap_alloc();
                }
                self.ledger.count_copy(part.len());
                small.extend_from_slice(part.as_slice());
            } else {
                if !small.is_empty() {
                    let staged = BufView::from_vec(std::mem::take(&mut small));
                    out.extend(self.send_view(staged));
                }
                out.extend(self.send_view(part.clone()));
            }
        }
        if !small.is_empty() {
            out.extend(self.send_view(BufView::from_vec(small)));
        }
        out
    }

    /// Process an incoming segment; returns segments to send back
    /// (ACKs and/or fast retransmissions).
    pub fn on_segment(&mut self, seg: &Segment) -> Vec<Segment> {
        let mut out = Vec::new();

        // --- sender side: process cumulative ACK ---
        // A corrupted/forged ACK can claim bytes we never sent; taking
        // it at face value would push `snd_una` past `snd_nxt` and
        // underflow `bytes_in_flight`. Clamp to `snd_nxt` and count.
        let ack = if seg.ack > self.snd_nxt {
            self.bad_acks += 1;
            self.snd_nxt
        } else {
            seg.ack
        };
        if ack > self.snd_una {
            self.snd_una = ack;
            self.dup_acks = 0;
            // Drop fully acked segments from the retransmit queue.
            // Cumulative ACKs cover a prefix of the seq-ordered map, so
            // popping from the front needs no scan and no allocation
            // (perf pass L3-5).
            while let Some((&s, p)) = self.unacked.first_key_value() {
                if s + p.len() as u64 <= ack {
                    self.unacked.pop_first();
                } else {
                    break;
                }
            }
        } else if ack == self.snd_una && seg.is_pure_ack() && !self.unacked.is_empty() {
            // Duplicate ACK.
            self.dup_acks += 1;
            if self.dup_acks >= 3 {
                // Fast retransmit: resend everything from snd_una
                // (Fig 11: "the client will resend all the requests that
                // have been offloaded to the DPU").
                for (seq, payload) in self.unacked.range(self.snd_una..) {
                    out.push(Segment {
                        seq: *seq,
                        payload: payload.clone(),
                        ack: self.rcv_nxt,
                    });
                    self.retransmitted_segments += 1;
                }
                self.dup_acks = 0;
            }
        }

        // --- receiver side: process payload ---
        if !seg.payload.is_empty() {
            if seg.seq == self.rcv_nxt {
                self.deliverable.push(seg.payload.clone());
                self.rcv_nxt = seg.seq_end();
                self.drain_ooo();
                out.push(self.pure_ack());
            } else if seg.seq > self.rcv_nxt {
                // Gap: buffer and emit a duplicate ACK for the hole.
                // Only within the receive window — an unbounded `ooo`
                // map would let one flow hold arbitrary memory.
                if seg.seq_end() <= self.rcv_nxt + RCV_WND {
                    // Keep the longer payload when ranges share a start
                    // (retransmits may re-slice at different bounds).
                    let p = self.ooo.entry(seg.seq).or_insert_with(|| seg.payload.clone());
                    if seg.payload.len() > p.len() {
                        *p = seg.payload.clone();
                    }
                } else {
                    self.ooo_window_drops += 1;
                }
                self.dup_acks_sent += 1;
                out.push(self.pure_ack());
            } else if seg.seq_end() > self.rcv_nxt {
                // Retransmit straddling the cursor (seq < rcv_nxt <
                // seq_end): the prefix is already delivered, but the
                // suffix is NEW data — dropping the whole segment (the
                // old behaviour) lost those bytes until a full-window
                // retransmit realigned them. Trim and deliver.
                let skip = (self.rcv_nxt - seg.seq) as usize;
                self.deliverable.push(seg.payload.slice(skip..seg.payload.len()));
                self.rcv_nxt = seg.seq_end();
                self.drain_ooo();
                out.push(self.pure_ack());
            } else {
                // Fully old data: re-ACK.
                out.push(self.pure_ack());
            }
        }
        out
    }

    /// Advance `rcv_nxt` through the out-of-order buffer: deliver
    /// contiguous entries, trim entries straddling the cursor, and
    /// purge entries fully behind it. Range-based, not exact-key — an
    /// ooo segment whose range got covered at a different alignment
    /// (e.g. buffered at 2000 but the cursor jumped 0→2500) used to be
    /// stranded forever, a per-flow leak under wire chaos.
    fn drain_ooo(&mut self) {
        while let Some((&seq, payload)) = self.ooo.first_key_value() {
            if seq > self.rcv_nxt {
                break; // still a hole before the next entry
            }
            let end = seq + payload.len() as u64;
            if end > self.rcv_nxt {
                let skip = (self.rcv_nxt - seq) as usize;
                let payload = self.ooo.pop_first().expect("peeked").1;
                self.deliverable.push(payload.slice(skip..payload.len()));
                self.rcv_nxt = end;
            } else {
                // Fully covered at another alignment: purge.
                self.ooo.pop_first();
            }
        }
    }

    /// Out-of-order segments currently buffered (bounded by
    /// [`RCV_WND`]; drained/purged as the cursor advances).
    pub fn ooo_len(&self) -> usize {
        self.ooo.len()
    }

    fn pure_ack(&self) -> Segment {
        Segment { seq: self.snd_nxt, payload: BufView::empty(), ack: self.rcv_nxt }
    }

    /// Drain bytes delivered in order to the application, materialized
    /// into one owned vector (an explicit, ledger-counted copy — prefer
    /// [`Self::deliver_rope`] on the data path).
    pub fn deliver(&mut self) -> Vec<u8> {
        let rope = std::mem::take(&mut self.deliverable);
        if !rope.is_empty() {
            self.ledger.count_heap_alloc();
            self.ledger.count_copy(rope.len());
        }
        rope.to_vec()
    }

    /// Drain delivered payloads as a zero-copy view rope.
    pub fn deliver_rope(&mut self) -> ByteRope {
        std::mem::take(&mut self.deliverable)
    }

    /// Retransmit everything outstanding (timeout path; used by tests to
    /// guarantee progress after loss).
    pub fn retransmit_all(&mut self) -> Vec<Segment> {
        let mut out = Vec::new();
        for (seq, payload) in self.unacked.range(self.snd_una..) {
            out.push(Segment { seq: *seq, payload: payload.clone(), ack: self.rcv_nxt });
            self.retransmitted_segments += 1;
        }
        out
    }

    /// Bytes sent but not yet acknowledged.
    pub fn bytes_in_flight(&self) -> u64 {
        self.snd_nxt - self.snd_una
    }

    /// Next expected receive sequence (visible for the director's
    /// sequence bookkeeping).
    pub fn rcv_nxt(&self) -> u64 {
        self.rcv_nxt
    }
}

/// Deliver `segs` from one endpoint to its peer, collecting replies;
/// loops until both directions quiesce. Test/functional-plane helper.
pub fn exchange(a: &mut TcpEndpoint, b: &mut TcpEndpoint, segs: Vec<Segment>) {
    let mut a_to_b = segs;
    let mut b_to_a: Vec<Segment> = Vec::new();
    while !a_to_b.is_empty() || !b_to_a.is_empty() {
        let mut next_b_to_a = Vec::new();
        for s in a_to_b.drain(..) {
            next_b_to_a.extend(b.on_segment(&s));
        }
        let mut next_a_to_b = Vec::new();
        for s in b_to_a.drain(..) {
            next_a_to_b.extend(a.on_segment(&s));
        }
        a_to_b = next_a_to_b;
        b_to_a = next_b_to_a;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_order_delivery() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let data: Vec<u8> = (0..5000).map(|i| (i % 256) as u8).collect();
        let segs = a.send(&data);
        assert_eq!(segs.len(), data.len().div_ceil(MSS));
        exchange(&mut a, &mut b, segs);
        assert_eq!(b.deliver(), data);
        assert_eq!(a.bytes_in_flight(), 0);
        assert_eq!(a.retransmitted_segments, 0);
    }

    #[test]
    fn out_of_order_reassembly() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let data: Vec<u8> = (0..4 * MSS).map(|i| (i % 251) as u8).collect();
        let mut segs = a.send(&data);
        segs.reverse(); // worst-case reordering
        for s in &segs {
            b.on_segment(s);
        }
        assert_eq!(b.deliver(), data);
    }

    #[test]
    fn lost_segment_recovered_by_fast_retransmit() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let data: Vec<u8> = (0..6 * MSS).map(|i| (i % 249) as u8).collect();
        let segs = a.send(&data);
        // Drop segment 1; deliver the rest — receiver dup-ACKs.
        let mut replies = Vec::new();
        for (i, s) in segs.iter().enumerate() {
            if i == 1 {
                continue;
            }
            replies.extend(b.on_segment(s));
        }
        assert!(b.dup_acks_sent >= 3);
        // Feed dup-ACKs back to the sender: fast retransmit fires.
        let mut retrans = Vec::new();
        for r in &replies {
            retrans.extend(a.on_segment(r));
        }
        assert!(a.retransmitted_segments > 0);
        // Deliver retransmissions; stream completes.
        exchange(&mut a, &mut b, retrans);
        assert_eq!(b.deliver(), data);
    }

    /// The Fig 11 pathology: a middlebox consumes ("offloads") segments
    /// mid-stream without splitting the connection. The host receiver
    /// sees a hole and forces the client to retransmit the offloaded
    /// bytes.
    #[test]
    fn partial_offload_without_pep_causes_retransmission_storm() {
        let mut client = TcpEndpoint::new();
        let mut host = TcpEndpoint::new();
        let data: Vec<u8> = (0..8 * MSS).map(|i| (i % 241) as u8).collect();
        let segs = client.send(&data);
        // DPU "offloads" (consumes) segments 1..=4 — they never reach
        // the host.
        let mut replies = Vec::new();
        for (i, s) in segs.iter().enumerate() {
            if (1..=4).contains(&i) {
                continue; // consumed by the DPU
            }
            replies.extend(host.on_segment(s));
        }
        // Host TCP dup-ACKed the gap.
        assert!(host.dup_acks_sent >= 3);
        let mut retrans = Vec::new();
        for r in &replies {
            retrans.extend(client.on_segment(r));
        }
        // Client retransmits ALL offloaded segments — wasted work.
        assert!(client.retransmitted_segments >= 4, "{}", client.retransmitted_segments);
    }

    /// With PEP splitting (§5.2) the DPU terminates the client
    /// connection, so offloaded requests are acked on connection 1 and
    /// only host-bound requests travel on connection 2 — no
    /// retransmissions anywhere.
    #[test]
    fn pep_split_avoids_retransmission() {
        let mut client = TcpEndpoint::new();
        let mut dpu_client_side = TcpEndpoint::new(); // conn 1 terminus
        let mut dpu_host_side = TcpEndpoint::new(); // conn 2 originator
        let mut host = TcpEndpoint::new();

        let data: Vec<u8> = (0..8 * MSS).map(|i| (i % 239) as u8).collect();
        let segs = client.send(&data);
        exchange(&mut client, &mut dpu_client_side, segs);
        let stream = dpu_client_side.deliver();
        assert_eq!(stream, data);

        // DPU offloads half, forwards half on the second connection.
        let host_bound = &stream[stream.len() / 2..];
        let fwd = dpu_host_side.send(host_bound);
        exchange(&mut dpu_host_side, &mut host, fwd);
        assert_eq!(host.deliver(), host_bound);

        assert_eq!(client.retransmitted_segments, 0);
        assert_eq!(dpu_host_side.retransmitted_segments, 0);
        assert_eq!(host.dup_acks_sent, 0);
    }

    /// Regression: after `retransmit_all`, the receiver sees every
    /// byte twice — `on_segment` must re-ACK the duplicates but never
    /// deliver a byte to the application twice.
    #[test]
    fn no_double_delivery_after_retransmit_all() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let data: Vec<u8> = (0..3 * MSS).map(|i| (i % 233) as u8).collect();
        let segs = a.send(&data);
        // Everything arrives, but the ACKs back to `a` are "lost".
        for s in &segs {
            b.on_segment(s);
        }
        assert_eq!(b.deliver(), data);
        // Sender times out and retransmits the whole window.
        let retrans = a.retransmit_all();
        assert_eq!(retrans.len(), segs.len(), "nothing was acked");
        let mut acks = Vec::new();
        for s in &retrans {
            acks.extend(b.on_segment(s));
        }
        assert!(b.deliver().is_empty(), "duplicates re-delivered to the app");
        assert_eq!(b.rcv_nxt(), data.len() as u64, "receive cursor must not move");
        // The duplicates still draw re-ACKs, so the sender can finally
        // prune its retransmit queue.
        assert!(!acks.is_empty());
        for s in &acks {
            a.on_segment(s);
        }
        assert_eq!(a.bytes_in_flight(), 0);
    }

    /// Regression: reordered + duplicated delivery (including a full
    /// duplicate pass after completion) delivers each byte exactly once.
    #[test]
    fn reordered_duplicates_deliver_each_byte_once() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let data: Vec<u8> = (0..5 * MSS).map(|i| (i % 229) as u8).collect();
        let segs = a.send(&data);
        // Adversarial arrival order with duplicates interleaved, every
        // segment present at least once.
        for &i in &[4usize, 1, 1, 3, 0, 2, 2, 0, 4, 3] {
            b.on_segment(&segs[i]);
        }
        assert_eq!(b.deliver(), data);
        // A late full retransmission storm changes nothing.
        for s in segs.iter().rev() {
            b.on_segment(s);
        }
        assert!(b.deliver().is_empty());
        assert_eq!(b.rcv_nxt(), data.len() as u64);
    }

    /// Regression: `retransmit_all` resends only the unacked suffix —
    /// a partial cumulative ACK prunes the front of the window.
    #[test]
    fn retransmit_all_respects_cumulative_acks() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let data = vec![8u8; 4 * MSS];
        let segs = a.send(&data);
        // Only segment 0 arrives; its ACK reaches the sender.
        let acks = b.on_segment(&segs[0]);
        for s in &acks {
            a.on_segment(s);
        }
        let retrans = a.retransmit_all();
        assert_eq!(retrans.len(), segs.len() - 1);
        assert_eq!(retrans[0].seq, MSS as u64, "retransmission starts at snd_una");
        exchange(&mut a, &mut b, retrans);
        assert_eq!(b.deliver(), data);
        assert_eq!(a.bytes_in_flight(), 0);
    }

    /// Satellite regression (zero-copy plane): `send` stages the burst
    /// into ONE buffer; wire segments, the retransmit queue, and
    /// `retransmit_all`'s output all reference it — no duplicate
    /// materialization of payload bytes anywhere on the send path.
    #[test]
    fn send_shares_one_buffer_across_segments_and_retransmits() {
        let mut a = TcpEndpoint::new();
        let data = vec![5u8; 3 * MSS];
        let before = a.ledger().snapshot();
        let segs = a.send(&data);
        let d = a.ledger().snapshot() - before;
        assert_eq!(d.heap_allocs, 1, "one staging buffer for the whole burst");
        assert_eq!(d.bytes_copied, data.len() as u64);
        for w in segs.windows(2) {
            assert!(w[0].payload.shares_storage(&w[1].payload));
        }
        // Timeout retransmission references the same storage: no copy.
        let before = a.ledger().snapshot();
        let retrans = a.retransmit_all();
        assert_eq!(retrans.len(), 3);
        for r in &retrans {
            assert!(r.payload.shares_storage(&segs[0].payload));
        }
        let d = a.ledger().snapshot() - before;
        assert_eq!((d.heap_allocs, d.bytes_copied), (0, 0));
    }

    /// Zero-copy receive: in-order payload views flow to the rope
    /// without copying; only explicit `deliver()` materializes.
    #[test]
    fn deliver_rope_aliases_segment_payloads() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let data: Vec<u8> = (0..2 * MSS).map(|i| (i % 251) as u8).collect();
        let segs = a.send(&data);
        for s in &segs {
            b.on_segment(s);
        }
        let before = b.ledger().snapshot();
        let rope = b.deliver_rope();
        assert_eq!(rope.to_vec(), data);
        assert!(rope.parts()[0].shares_storage(&segs[0].payload));
        let d = b.ledger().snapshot() - before;
        assert_eq!((d.heap_allocs, d.bytes_copied), (0, 0));
    }

    #[test]
    fn send_rope_references_bulk_parts_without_copying() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let mut rope = crate::buf::ByteRope::new();
        rope.push(crate::buf::BufView::from_vec(vec![1u8; 700]));
        rope.push(crate::buf::BufView::from_vec(vec![2u8; 2 * MSS + 7]));
        let expect = rope.to_vec();
        let before = a.ledger().snapshot();
        let segs = a.send_rope(rope);
        let d = a.ledger().snapshot() - before;
        assert_eq!((d.heap_allocs, d.bytes_copied), (0, 0), "bulk parts ride by reference");
        assert!(segs.len() >= 4, "large part split at MSS");
        exchange(&mut a, &mut b, segs);
        assert_eq!(b.deliver(), expect);
        assert_eq!(a.bytes_in_flight(), 0);
    }

    /// Small rope parts (frame headers, tiny KV payloads) coalesce into
    /// MSS-packed segments instead of one tiny segment per part — the
    /// copy is counted, the segment count stays bounded.
    #[test]
    fn send_rope_coalesces_small_parts() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let mut rope = crate::buf::ByteRope::new();
        // 60 frames of 19-byte header + 32-byte payload: 120 parts.
        for i in 0..60u8 {
            rope.push(crate::buf::BufView::from_vec(vec![i; 19]));
            rope.push(crate::buf::BufView::from_vec(vec![i ^ 0xff; 32]));
        }
        let expect = rope.to_vec();
        let total = expect.len();
        let before = a.ledger().snapshot();
        let segs = a.send_rope(rope);
        let d = a.ledger().snapshot() - before;
        assert_eq!(segs.len(), total.div_ceil(MSS), "MSS-packed, not per-part");
        assert_eq!(d.heap_allocs, 1, "one staging buffer for the whole small run");
        assert_eq!(d.bytes_copied, total as u64);
        exchange(&mut a, &mut b, segs);
        assert_eq!(b.deliver(), expect);
    }

    /// Helper: a raw data segment over arbitrary bytes (for crafting
    /// misaligned retransmits that `send` would never produce).
    fn raw_seg(seq: u64, bytes: &[u8]) -> Segment {
        Segment { seq, payload: BufView::from_vec(bytes.to_vec()), ack: 0 }
    }

    /// Satellite regression: a retransmitted segment straddling
    /// `rcv_nxt` (`seq < rcv_nxt < seq_end`) used to be dropped whole
    /// as "old/overlapping data", losing its unseen tail bytes until a
    /// full-window retransmit happened to realign. The covered prefix
    /// must be trimmed and the new suffix delivered.
    #[test]
    fn straddling_retransmit_delivers_unseen_suffix() {
        let data: Vec<u8> = (0..2000).map(|i| (i % 211) as u8).collect();
        let mut b = TcpEndpoint::new();
        // [0, 1000) arrives; cursor at 1000.
        b.on_segment(&raw_seg(0, &data[..1000]));
        assert_eq!(b.rcv_nxt(), 1000);
        // Misaligned retransmit [600, 1700): 400 already-seen bytes +
        // 700 new ones.
        let acks = b.on_segment(&raw_seg(600, &data[600..1700]));
        assert_eq!(b.rcv_nxt(), 1700, "cursor must advance over the new suffix");
        assert_eq!(acks.len(), 1);
        assert_eq!(acks[0].ack, 1700);
        // Tail closes the stream; delivery is byte-exact, no dupes.
        b.on_segment(&raw_seg(1700, &data[1700..]));
        assert_eq!(b.deliver(), data);
    }

    /// Satellite regression: an out-of-order entry whose range is
    /// later covered at a DIFFERENT alignment used to be stranded in
    /// `ooo` forever (the pull loop only matched exact keys) — a
    /// per-flow memory leak under wire chaos. The cursor advance must
    /// purge covered entries and trim straddled ones.
    #[test]
    fn stale_ooo_purged_and_trimmed_on_cursor_advance() {
        let data: Vec<u8> = (0..3500).map(|i| (i % 199) as u8).collect();
        let mut b = TcpEndpoint::new();
        // [2000, 3000) arrives early → buffered out of order.
        b.on_segment(&raw_seg(2000, &data[2000..3000]));
        assert_eq!(b.ooo_len(), 1);
        // [0, 2500) fills the hole at a different alignment: the ooo
        // entry now straddles the cursor — its [2500, 3000) suffix
        // must be delivered, not stranded.
        b.on_segment(&raw_seg(0, &data[..2500]));
        assert_eq!(b.rcv_nxt(), 3000, "straddled ooo entry trimmed and delivered");
        assert_eq!(b.ooo_len(), 0, "no stale entry may remain");
        // [1500, 3500): prefix old, suffix new — closes the stream.
        b.on_segment(&raw_seg(1500, &data[1500..]));
        assert_eq!(b.rcv_nxt(), 3500);
        assert_eq!(b.deliver(), data);
        // A fully-covered duplicate buffered early is purged too.
        let mut c = TcpEndpoint::new();
        c.on_segment(&raw_seg(100, &data[100..200]));
        assert_eq!(c.ooo_len(), 1);
        c.on_segment(&raw_seg(0, &data[..300]));
        assert_eq!(c.ooo_len(), 0, "covered entry purged, not leaked");
        assert_eq!(c.deliver(), data[..300].to_vec());
    }

    /// Satellite regression: the `ooo` buffer is bounded by the
    /// receive window — segments past `rcv_nxt + RCV_WND` are refused
    /// (and counted), so one adversarial flow can't hold unbounded
    /// reassembly memory at 10k flows.
    #[test]
    fn ooo_buffer_bounded_by_receive_window() {
        let mut b = TcpEndpoint::new();
        // Within the window: buffered.
        b.on_segment(&raw_seg(MSS as u64, &vec![7u8; MSS]));
        assert_eq!(b.ooo_len(), 1);
        // Far beyond the window: refused, counted, still dup-ACKed.
        let far = RCV_WND + 10 * MSS as u64;
        let acks = b.on_segment(&raw_seg(far, &vec![9u8; MSS]));
        assert_eq!(b.ooo_len(), 1, "out-of-window segment must not be buffered");
        assert_eq!(b.ooo_window_drops, 1);
        assert_eq!(acks.len(), 1, "refused segment still draws an ACK");
        // The in-window stream is unaffected.
        let data = vec![3u8; 2 * MSS];
        b.on_segment(&raw_seg(0, &data[..MSS]));
        assert_eq!(b.rcv_nxt(), 2 * MSS as u64);
        let mut expect = data[..MSS].to_vec();
        expect.extend_from_slice(&vec![7u8; MSS]);
        assert_eq!(b.deliver(), expect);
    }

    /// Satellite regression: a corrupted/forged ACK claiming bytes we
    /// never sent used to push `snd_una` past `snd_nxt`, underflowing
    /// `bytes_in_flight` (debug panic / absurd release value). It must
    /// be clamped to `snd_nxt` and counted.
    #[test]
    fn forged_ack_clamped_not_underflowing() {
        let mut a = TcpEndpoint::new();
        let segs = a.send(&vec![1u8; 2 * MSS]);
        assert_eq!(a.bytes_in_flight(), 2 * MSS as u64);
        // Forged ACK far past snd_nxt.
        let forged = Segment { seq: 0, payload: BufView::empty(), ack: u64::MAX / 2 };
        a.on_segment(&forged);
        assert_eq!(a.bad_acks, 1);
        assert_eq!(a.bytes_in_flight(), 0, "clamped to snd_nxt — no underflow");
        // The retransmit queue is fully pruned by the clamped ACK and
        // the connection keeps working.
        assert!(a.retransmit_all().is_empty());
        let more = a.send(&vec![2u8; MSS]);
        assert_eq!(more.len(), 1);
        assert_eq!(more[0].seq, segs.len() as u64 * MSS as u64);
    }

    #[test]
    fn timeout_retransmit_covers_tail_loss() {
        let mut a = TcpEndpoint::new();
        let mut b = TcpEndpoint::new();
        let data = vec![3u8; 2 * MSS];
        let segs = a.send(&data);
        // Lose the LAST segment (no dup-ACKs possible).
        b.on_segment(&segs[0]);
        assert!(a.bytes_in_flight() > 0);
        let retrans = a.retransmit_all();
        exchange(&mut a, &mut b, retrans);
        assert_eq!(b.deliver(), data);
    }
}
