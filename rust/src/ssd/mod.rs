//! NVMe SSD model.
//!
//! The paper's storage servers carry a 1 TB NVMe SSD driven through SPDK
//! from the DPU (§4.3, §7). Here the device is an in-memory block store
//! with the same interface shape:
//!
//! * [`Ssd`] — the device: block-addressed, byte-payload reads/writes
//!   with optional injected latency (for functional-plane timing tests).
//! * [`AsyncSsd`] — an SPDK-like asynchronous submission/completion
//!   facade over worker threads, used by the DPU file service to exercise
//!   its pending→complete ordered-delivery machinery (§4.3 "Ordered
//!   execution") against genuinely out-of-order completions.
//!
//! Data round-trips for real, so the whole functional plane (file system,
//! file service, offload engine, applications) is testable end to end.

mod r#async;

pub use r#async::{AsyncSsd, Completion, SsdOp};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, RwLock};

/// Errors surfaced by the device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SsdError {
    OutOfRange { addr: u64, len: usize, capacity: u64 },
    /// The fault-injection plane failed this op
    /// ([`crate::fault::SsdFault::Fail`]).
    Injected,
    /// The fault plane cut power ([`Ssd::arm_power_cut`]): the armed
    /// write persisted only a prefix of its bytes and every op until
    /// [`Ssd::power_restore`] fails with this error.
    PowerLost,
}

impl std::fmt::Display for SsdError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SsdError::OutOfRange { addr, len, capacity } => {
                write!(f, "I/O out of range: addr={addr} len={len} capacity={capacity}")
            }
            SsdError::Injected => write!(f, "injected fault"),
            SsdError::PowerLost => write!(f, "power lost"),
        }
    }
}

impl std::error::Error for SsdError {}

/// Power-cut / write-trace state behind [`Ssd::arm_power_cut`].
#[derive(Default)]
struct PowerInner {
    /// `(write index since arm, bytes that persist)` — the pending
    /// cuts, possibly several. Every listed write tears; the
    /// highest-indexed one also kills the device (the earlier ones
    /// model a volatile write cache acking writes the medium never
    /// fully absorbed before the same power loss).
    cuts: Vec<(u64, usize)>,
    /// Torn-sector mode: a torn write persists only down to a sector
    /// boundary and the sector the cut landed in fills with
    /// deterministic garbage instead of a clean prefix — the shape a
    /// real NVMe device presents when a program operation dies
    /// mid-sector. Checksums, not prefix structure, must catch it.
    torn_sector: bool,
    /// Writes seen since the last arm / trace start.
    writes_seen: u64,
    /// `(addr, len)` per write while tracing (crash-point enumeration).
    trace: Option<Vec<(u64, usize)>>,
}

/// How [`Ssd::power_gate`] says a write must land.
struct Tear {
    /// Bytes of the write that persist.
    persist: usize,
    /// Fill the sector after the persisted prefix with deterministic
    /// garbage (torn-sector mode).
    garbage: bool,
    /// This is the highest-indexed armed cut: the device dies and the
    /// write errors with [`SsdError::PowerLost`]. Non-fatal tears
    /// return `Ok` to the caller — the write-cache ack the crash later
    /// betrays.
    fatal: bool,
}

/// In-memory NVMe-like block device.
pub struct Ssd {
    data: RwLock<Box<[u8]>>,
    block_size: usize,
    capacity: u64,
    /// Power is out: every op fails until [`Self::power_restore`].
    dead: AtomicBool,
    /// A cut is armed or a trace is running (gates the write slow
    /// path, so the uninstrumented hot path never takes `power`).
    power_hook: AtomicBool,
    power: Mutex<PowerInner>,
}

impl Ssd {
    /// Create a device of `capacity` bytes with the given block size.
    pub fn new(capacity: u64, block_size: usize) -> Self {
        assert!(block_size.is_power_of_two());
        assert_eq!(capacity % block_size as u64, 0);
        Ssd {
            data: RwLock::new(vec![0u8; capacity as usize].into_boxed_slice()),
            block_size,
            capacity,
            dead: AtomicBool::new(false),
            power_hook: AtomicBool::new(false),
            power: Mutex::new(PowerInner::default()),
        }
    }

    /// Arm a deterministic power cut: counting from now, the
    /// `cut_write`-th write (0-based) persists only its first
    /// `cut_bytes` bytes — a torn write — and then the device goes dead
    /// (every subsequent op fails with [`SsdError::PowerLost`]) until
    /// [`Self::power_restore`]. `cut_bytes >=` the write's length
    /// means the write completes and power dies right after it.
    pub fn arm_power_cut(&self, cut_write: u64, cut_bytes: usize) {
        self.arm_power_cuts(&[(cut_write, cut_bytes)], false);
    }

    /// Arm several interleaved tears from one power event: every
    /// `(write index, persisted bytes)` listed tears, and the
    /// highest-indexed one kills the device. The earlier tears return
    /// `Ok` to their callers — a volatile write cache acked them, the
    /// medium only kept a prefix — which is exactly the lie the
    /// durability contract has to survive. With `torn_sector` set,
    /// each tear persists only down to a sector boundary and fills the
    /// cut sector with deterministic garbage (`0xA5 ^ offset`), so
    /// recovery must rely on checksums rather than clean-prefix
    /// structure.
    pub fn arm_power_cuts(&self, cuts: &[(u64, usize)], torn_sector: bool) {
        assert!(!cuts.is_empty(), "arming zero cuts is a no-op bug");
        let mut p = self.power.lock().unwrap();
        p.cuts = cuts.to_vec();
        p.torn_sector = torn_sector;
        p.writes_seen = 0;
        self.dead.store(false, Ordering::SeqCst);
        self.power_hook.store(true, Ordering::SeqCst);
    }

    /// Power the device back on (the reboot before a remount). The
    /// bytes that survived the cut stay exactly as they landed.
    pub fn power_restore(&self) {
        let mut p = self.power.lock().unwrap();
        p.cuts.clear();
        p.torn_sector = false;
        self.dead.store(false, Ordering::SeqCst);
        self.power_hook.store(p.trace.is_some(), Ordering::SeqCst);
    }

    /// Whether an armed cut has fired and the device is off.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Start recording `(addr, len)` of every subsequent write — the
    /// scout pass of crash-point enumeration.
    pub fn start_write_trace(&self) {
        let mut p = self.power.lock().unwrap();
        p.trace = Some(Vec::new());
        p.writes_seen = 0;
        self.power_hook.store(true, Ordering::SeqCst);
    }

    /// Stop tracing and return the recorded write schedule.
    pub fn take_write_trace(&self) -> Vec<(u64, usize)> {
        let mut p = self.power.lock().unwrap();
        let t = p.trace.take().unwrap_or_default();
        self.power_hook.store(!p.cuts.is_empty(), Ordering::SeqCst);
        t
    }

    /// Count/trace this write; `Some(tear)` means it is an armed cut
    /// and lands torn as the [`Tear`] describes.
    fn power_gate(&self, addr: u64, len: usize) -> Option<Tear> {
        let mut p = self.power.lock().unwrap();
        let w = p.writes_seen;
        p.writes_seen += 1;
        if let Some(t) = p.trace.as_mut() {
            t.push((addr, len));
        }
        let cut_b = p.cuts.iter().find(|(cw, _)| *cw == w).map(|(_, cb)| *cb)?;
        let fatal = p.cuts.iter().all(|(cw, _)| *cw <= w);
        if fatal {
            self.dead.store(true, Ordering::SeqCst);
        }
        let mut persist = cut_b.min(len);
        let garbage = p.torn_sector && persist < len;
        if garbage {
            persist -= persist % self.block_size;
        }
        Some(Tear { persist, garbage, fatal })
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    fn check(&self, addr: u64, len: usize) -> Result<(), SsdError> {
        if addr.checked_add(len as u64).map(|e| e <= self.capacity) != Some(true) {
            return Err(SsdError::OutOfRange { addr, len, capacity: self.capacity });
        }
        Ok(())
    }

    /// Read `buf.len()` bytes at `addr` directly into the caller's buffer
    /// (the zero-copy contract of §4.3: the driver writes into the
    /// pre-allocated response space).
    pub fn read_into(&self, addr: u64, buf: &mut [u8]) -> Result<(), SsdError> {
        self.check(addr, buf.len())?;
        if self.dead.load(Ordering::Relaxed) {
            return Err(SsdError::PowerLost);
        }
        let data = self.data.read().unwrap();
        buf.copy_from_slice(&data[addr as usize..addr as usize + buf.len()]);
        Ok(())
    }

    /// Write the caller's buffer at `addr` (driver reads directly from
    /// the request buffer — no staging copy).
    pub fn write_from(&self, addr: u64, buf: &[u8]) -> Result<(), SsdError> {
        self.check(addr, buf.len())?;
        if self.dead.load(Ordering::Relaxed) {
            return Err(SsdError::PowerLost);
        }
        if self.power_hook.load(Ordering::Relaxed) {
            if let Some(t) = self.power_gate(addr, buf.len()) {
                // Torn write: the persisted prefix lands, the rest
                // never makes it to the medium.
                let mut data = self.data.write().unwrap();
                data[addr as usize..addr as usize + t.persist]
                    .copy_from_slice(&buf[..t.persist]);
                if t.garbage {
                    // Torn-sector mode: the sector the cut landed in
                    // holds deterministic garbage, not old or new
                    // bytes.
                    let end = (t.persist + self.block_size).min(buf.len());
                    for i in t.persist..end {
                        data[addr as usize + i] = 0xA5 ^ (i as u8);
                    }
                }
                drop(data);
                if t.fatal {
                    return Err(SsdError::PowerLost);
                }
                // Non-fatal tear: the volatile write cache acks it —
                // the caller learns nothing until recovery.
                return Ok(());
            }
        }
        let mut data = self.data.write().unwrap();
        data[addr as usize..addr as usize + buf.len()].copy_from_slice(buf);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ssd = Ssd::new(1 << 20, 512);
        let payload: Vec<u8> = (0..4096).map(|i| (i % 251) as u8).collect();
        ssd.write_from(8192, &payload).unwrap();
        let mut out = vec![0u8; 4096];
        ssd.read_into(8192, &mut out).unwrap();
        assert_eq!(out, payload);
    }

    #[test]
    fn bounds_checked() {
        let ssd = Ssd::new(4096, 512);
        let mut buf = [0u8; 64];
        assert!(ssd.read_into(4090, &mut buf).is_err());
        assert!(ssd.write_from(u64::MAX - 2, &buf[..8]).is_err());
        assert!(ssd.read_into(4032, &mut buf).is_ok());
    }

    #[test]
    fn unwritten_reads_zero() {
        let ssd = Ssd::new(1 << 16, 512);
        let mut buf = [0xffu8; 128];
        ssd.read_into(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&b| b == 0));
    }

    #[test]
    fn power_cut_tears_the_armed_write_and_kills_the_device() {
        let ssd = Ssd::new(1 << 16, 512);
        ssd.write_from(0, &[1u8; 64]).unwrap();
        // Cut the second write (index 1, counting from arm) at 10 bytes.
        ssd.arm_power_cut(1, 10);
        ssd.write_from(100, &[2u8; 32]).unwrap();
        assert_eq!(ssd.write_from(200, &[3u8; 32]), Err(SsdError::PowerLost));
        assert!(ssd.is_dead());
        // Dead device: everything fails.
        assert_eq!(ssd.write_from(0, &[4u8; 8]), Err(SsdError::PowerLost));
        assert_eq!(ssd.read_into(0, &mut [0u8; 8]), Err(SsdError::PowerLost));
        // Reboot: surviving bytes are exactly the torn prefix.
        ssd.power_restore();
        let mut buf = [0u8; 32];
        ssd.read_into(200, &mut buf).unwrap();
        assert_eq!(&buf[..10], &[3u8; 10]);
        assert!(buf[10..].iter().all(|&b| b == 0), "bytes past the cut never landed");
        ssd.read_into(100, &mut buf).unwrap();
        assert_eq!(buf, [2u8; 32], "write before the cut is intact");
        // Power restored and the cut disarmed: writes work again.
        ssd.write_from(300, &[5u8; 8]).unwrap();
    }

    #[test]
    fn cut_at_full_length_completes_the_write_then_dies() {
        let ssd = Ssd::new(1 << 16, 512);
        ssd.arm_power_cut(0, usize::MAX);
        assert_eq!(ssd.write_from(0, &[7u8; 16]), Err(SsdError::PowerLost));
        ssd.power_restore();
        let mut buf = [0u8; 16];
        ssd.read_into(0, &mut buf).unwrap();
        assert_eq!(buf, [7u8; 16]);
    }

    #[test]
    fn multi_cut_tears_earlier_writes_silently_and_dies_on_the_last() {
        let ssd = Ssd::new(1 << 16, 512);
        // Writes 0 and 2 tear; write 2 is the highest-indexed cut and
        // kills the device. Write 1 is untouched.
        ssd.arm_power_cuts(&[(0, 4), (2, 8)], false);
        assert_eq!(ssd.write_from(0, &[1u8; 16]), Ok(()), "cached ack despite the tear");
        assert_eq!(ssd.write_from(512, &[2u8; 16]), Ok(()));
        assert_eq!(ssd.write_from(1024, &[3u8; 16]), Err(SsdError::PowerLost));
        assert!(ssd.is_dead());
        ssd.power_restore();
        let mut buf = [0u8; 16];
        ssd.read_into(0, &mut buf).unwrap();
        assert_eq!(&buf[..4], &[1u8; 4]);
        assert!(buf[4..].iter().all(|&b| b == 0), "acked write silently lost its tail");
        ssd.read_into(512, &mut buf).unwrap();
        assert_eq!(buf, [2u8; 16], "unlisted write is intact");
        ssd.read_into(1024, &mut buf).unwrap();
        assert_eq!(&buf[..8], &[3u8; 8]);
        assert!(buf[8..].iter().all(|&b| b == 0));
    }

    #[test]
    fn torn_sector_mode_persists_to_sector_boundary_and_garbages_the_cut_sector() {
        let ssd = Ssd::new(1 << 16, 512);
        // Cut at byte 700 of a 1536-byte write: persists rounds down to
        // 512, sector [512, 1024) fills with garbage, the rest never
        // lands.
        ssd.arm_power_cuts(&[(0, 700)], true);
        assert_eq!(ssd.write_from(0, &vec![7u8; 1536]), Err(SsdError::PowerLost));
        ssd.power_restore();
        let mut buf = vec![0u8; 1536];
        ssd.read_into(0, &mut buf).unwrap();
        assert_eq!(&buf[..512], &vec![7u8; 512][..], "prefix lands sector-aligned");
        for (i, &b) in buf[512..1024].iter().enumerate() {
            let off = 512 + i;
            assert_eq!(b, 0xA5 ^ (off as u8), "cut sector holds deterministic garbage");
        }
        assert!(buf[1024..].iter().all(|&b| b == 0), "sectors past the cut never landed");
        // Same schedule, same garbage: the matrix replays byte-exact.
        let ssd2 = Ssd::new(1 << 16, 512);
        ssd2.arm_power_cuts(&[(0, 700)], true);
        let _ = ssd2.write_from(0, &vec![7u8; 1536]);
        ssd2.power_restore();
        let mut buf2 = vec![0u8; 1536];
        ssd2.read_into(0, &mut buf2).unwrap();
        assert_eq!(buf, buf2);
    }

    #[test]
    fn write_trace_records_the_schedule() {
        let ssd = Ssd::new(1 << 16, 512);
        ssd.write_from(0, &[0u8; 8]).unwrap(); // pre-trace: not recorded
        ssd.start_write_trace();
        ssd.write_from(512, &[1u8; 100]).unwrap();
        ssd.write_from(4096, &[2u8; 7]).unwrap();
        assert_eq!(ssd.take_write_trace(), vec![(512, 100), (4096, 7)]);
        // Trace consumed; a second take is empty.
        assert!(ssd.take_write_trace().is_empty());
    }
}
