//! NVMe SSD model.
//!
//! The paper's storage servers carry a 1 TB NVMe SSD driven through SPDK
//! from the DPU (§4.3, §7). Here the device is an in-memory block store
//! with the same interface shape:
//!
//! * [`Ssd`] — the device: block-addressed, byte-payload reads/writes
//!   with optional injected latency (for functional-plane timing tests).
//! * [`AsyncSsd`] — an SPDK-like asynchronous submission/completion
//!   facade over worker threads, used by the DPU file service to exercise
//!   its pending→complete ordered-delivery machinery (§4.3 "Ordered
//!   execution") against genuinely out-of-order completions.
//!
//! Data round-trips for real, so the whole functional plane (file system,
//! file service, offload engine, applications) is testable end to end.

mod r#async;

pub use r#async::{AsyncSsd, Completion, SsdOp};

use std::sync::RwLock;

/// Errors surfaced by the device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SsdError {
    OutOfRange { addr: u64, len: usize, capacity: u64 },
    /// The fault-injection plane failed this op
    /// ([`crate::fault::SsdFault::Fail`]).
    Injected,
}

impl std::fmt::Display for SsdError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SsdError::OutOfRange { addr, len, capacity } => {
                write!(f, "I/O out of range: addr={addr} len={len} capacity={capacity}")
            }
            SsdError::Injected => write!(f, "injected fault"),
        }
    }
}

impl std::error::Error for SsdError {}

/// In-memory NVMe-like block device.
pub struct Ssd {
    data: RwLock<Box<[u8]>>,
    block_size: usize,
    capacity: u64,
}

impl Ssd {
    /// Create a device of `capacity` bytes with the given block size.
    pub fn new(capacity: u64, block_size: usize) -> Self {
        assert!(block_size.is_power_of_two());
        assert_eq!(capacity % block_size as u64, 0);
        Ssd {
            data: RwLock::new(vec![0u8; capacity as usize].into_boxed_slice()),
            block_size,
            capacity,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    fn check(&self, addr: u64, len: usize) -> Result<(), SsdError> {
        if addr.checked_add(len as u64).map(|e| e <= self.capacity) != Some(true) {
            return Err(SsdError::OutOfRange { addr, len, capacity: self.capacity });
        }
        Ok(())
    }

    /// Read `buf.len()` bytes at `addr` directly into the caller's buffer
    /// (the zero-copy contract of §4.3: the driver writes into the
    /// pre-allocated response space).
    pub fn read_into(&self, addr: u64, buf: &mut [u8]) -> Result<(), SsdError> {
        self.check(addr, buf.len())?;
        let data = self.data.read().unwrap();
        buf.copy_from_slice(&data[addr as usize..addr as usize + buf.len()]);
        Ok(())
    }

    /// Write the caller's buffer at `addr` (driver reads directly from
    /// the request buffer — no staging copy).
    pub fn write_from(&self, addr: u64, buf: &[u8]) -> Result<(), SsdError> {
        self.check(addr, buf.len())?;
        let mut data = self.data.write().unwrap();
        data[addr as usize..addr as usize + buf.len()].copy_from_slice(buf);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ssd = Ssd::new(1 << 20, 512);
        let payload: Vec<u8> = (0..4096).map(|i| (i % 251) as u8).collect();
        ssd.write_from(8192, &payload).unwrap();
        let mut out = vec![0u8; 4096];
        ssd.read_into(8192, &mut out).unwrap();
        assert_eq!(out, payload);
    }

    #[test]
    fn bounds_checked() {
        let ssd = Ssd::new(4096, 512);
        let mut buf = [0u8; 64];
        assert!(ssd.read_into(4090, &mut buf).is_err());
        assert!(ssd.write_from(u64::MAX - 2, &buf[..8]).is_err());
        assert!(ssd.read_into(4032, &mut buf).is_ok());
    }

    #[test]
    fn unwritten_reads_zero() {
        let ssd = Ssd::new(1 << 16, 512);
        let mut buf = [0xffu8; 128];
        ssd.read_into(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&b| b == 0));
    }
}
