//! SPDK-like asynchronous submission/completion facade (§7: the DMA
//! thread sends operations to SPDK workers via `spdk_thread_send_msg`;
//! workers submit `spdk_bdev_read/write` and populate the response on
//! completion).
//!
//! Worker threads execute ops against the in-memory [`Ssd`] and post
//! [`Completion`]s to a shared queue the file service polls. With more
//! than one worker, completions genuinely arrive out of submission
//! order, exercising the TailA/TailB/TailC ordered-delivery logic.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::{Ssd, SsdError};

/// A submitted operation. Buffers travel with the op (the functional
/// analog of pointing the driver at request/response buffer memory).
#[derive(Debug)]
pub enum SsdOp {
    Read { addr: u64, len: usize },
    Write { addr: u64, data: Vec<u8> },
}

/// Completion posted by a worker.
#[derive(Debug)]
pub struct Completion {
    /// Caller-chosen tag (e.g. response-buffer slot index).
    pub tag: u64,
    /// Read payload (empty for writes).
    pub data: Vec<u8>,
    pub result: Result<(), SsdError>,
}

enum Job {
    Op { tag: u64, op: SsdOp },
    Stop,
}

/// Async facade over [`Ssd`] with `workers` SPDK-like worker threads.
///
/// `workers == 0` selects **inline (polled) mode**: operations execute
/// synchronously at submit time on the caller's thread and only the
/// completion queue is deferred. This mirrors SPDK's polled-mode
/// driver and is the right choice on few-core hosts — the perf pass
/// found the worker handoff (mutex + context switch) dominating the
/// single-core profile (EXPERIMENTS.md §Perf L3-3). Completions still
/// flow through `poll()`, so callers exercise the same
/// pending→complete machinery.
pub struct AsyncSsd {
    tx: Option<mpsc::Sender<Job>>,
    /// Inline-mode execution target.
    inline_ssd: Option<Arc<Ssd>>,
    completions: Arc<Mutex<VecDeque<Completion>>>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    /// Queue-depth accounting: ops submitted / completions drained by
    /// the owner of this queue.
    submitted: AtomicU64,
    polled: AtomicU64,
}

impl AsyncSsd {
    /// Inline (polled) mode — see struct docs.
    pub fn new_inline(ssd: Arc<Ssd>) -> Self {
        AsyncSsd {
            tx: None,
            inline_ssd: Some(ssd),
            completions: Arc::new(Mutex::new(VecDeque::new())),
            handles: Vec::new(),
            workers: 0,
            submitted: AtomicU64::new(0),
            polled: AtomicU64::new(0),
        }
    }

    /// Per-shard submission queues over one shared device (§7).
    ///
    /// Each returned queue has its own submission channel, its own
    /// completion queue and its own workers (`workers_per_queue == 0`
    /// selects inline polled mode per queue), so shards submitting and
    /// polling concurrently never contend on a shared queue lock — the
    /// only shared structure is the device itself.
    pub fn shard_queues(
        ssd: &Arc<Ssd>,
        queues: usize,
        workers_per_queue: usize,
    ) -> Vec<AsyncSsd> {
        assert!(queues >= 1);
        (0..queues).map(|_| AsyncSsd::new(ssd.clone(), workers_per_queue)).collect()
    }

    pub fn new(ssd: Arc<Ssd>, workers: usize) -> Self {
        if workers == 0 {
            return Self::new_inline(ssd);
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let completions = Arc::new(Mutex::new(VecDeque::new()));
        let mut handles = Vec::new();
        for _ in 0..workers {
            let rx = rx.clone();
            let ssd = ssd.clone();
            let completions = completions.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = { rx.lock().unwrap().recv() };
                match job {
                    Ok(Job::Op { tag, op }) => {
                        let completion = match op {
                            SsdOp::Read { addr, len } => {
                                let mut buf = vec![0u8; len];
                                let result = ssd.read_into(addr, &mut buf);
                                Completion { tag, data: buf, result }
                            }
                            SsdOp::Write { addr, data } => {
                                let result = ssd.write_from(addr, &data);
                                Completion { tag, data: Vec::new(), result }
                            }
                        };
                        completions.lock().unwrap().push_back(completion);
                    }
                    Ok(Job::Stop) | Err(_) => break,
                }
            }));
        }
        AsyncSsd {
            tx: Some(tx),
            inline_ssd: None,
            completions,
            handles,
            workers,
            submitted: AtomicU64::new(0),
            polled: AtomicU64::new(0),
        }
    }

    /// Submit an operation with a caller tag; returns immediately in
    /// worker mode, after synchronous execution in inline mode.
    pub fn submit(&self, tag: u64, op: SsdOp) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        if let Some(ssd) = &self.inline_ssd {
            let completion = match op {
                SsdOp::Read { addr, len } => {
                    let mut buf = vec![0u8; len];
                    let result = ssd.read_into(addr, &mut buf);
                    Completion { tag, data: buf, result }
                }
                SsdOp::Write { addr, data } => {
                    let result = ssd.write_from(addr, &data);
                    Completion { tag, data: Vec::new(), result }
                }
            };
            self.completions.lock().unwrap().push_back(completion);
            return;
        }
        self.tx.as_ref().unwrap().send(Job::Op { tag, op }).expect("ssd workers alive");
    }

    /// Poll completed operations (drains up to `max`).
    pub fn poll(&self, max: usize) -> Vec<Completion> {
        let mut q = self.completions.lock().unwrap();
        let n = q.len().min(max);
        if n > 0 {
            self.polled.fetch_add(n as u64, Ordering::Relaxed);
        }
        q.drain(..n).collect()
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Operations submitted on this queue so far.
    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Ops submitted but whose completions have not been drained yet
    /// (the queue depth a shard sees on its own queue).
    pub fn in_flight(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed) - self.polled.load(Ordering::Relaxed)
    }
}

impl Drop for AsyncSsd {
    fn drop(&mut self) {
        if let Some(tx) = &self.tx {
            for _ in 0..self.handles.len() {
                let _ = tx.send(Job::Stop);
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_roundtrip() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 2);
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![42u8; 512] });
        // Wait for write completion.
        let mut done = Vec::new();
        while done.is_empty() {
            done = aio.poll(16);
        }
        assert_eq!(done[0].tag, 1);
        assert!(done[0].result.is_ok());

        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        let mut done = Vec::new();
        while done.is_empty() {
            done = aio.poll(16);
        }
        assert_eq!(done[0].tag, 2);
        assert_eq!(done[0].data, vec![42u8; 512]);
    }

    #[test]
    fn many_outstanding_all_complete() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 4);
        let n = 256;
        for i in 0..n {
            aio.submit(i, SsdOp::Write { addr: (i % 128) * 512, data: vec![i as u8; 512] });
        }
        let mut tags = Vec::new();
        while tags.len() < n as usize {
            for c in aio.poll(64) {
                assert!(c.result.is_ok());
                tags.push(c.tag);
            }
        }
        tags.sort_unstable();
        assert_eq!(tags, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn inline_mode_same_contract() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd);
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![9u8; 512] });
        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        let done = aio.poll(16);
        assert_eq!(done.len(), 2);
        assert_eq!(done[1].data, vec![9u8; 512]);
        assert_eq!(aio.workers(), 0);
    }

    #[test]
    fn shard_queues_are_independent() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let queues = AsyncSsd::shard_queues(&ssd, 3, 0);
        assert_eq!(queues.len(), 3);
        queues[0].submit(1, SsdOp::Write { addr: 0, data: vec![5u8; 512] });
        queues[1].submit(2, SsdOp::Read { addr: 0, len: 512 });
        // Completions stay on the queue that submitted them; other
        // queues observe nothing.
        assert!(queues[2].poll(16).is_empty());
        assert_eq!(queues[0].in_flight(), 1);
        let c0 = queues[0].poll(16);
        assert_eq!(c0.len(), 1);
        assert_eq!(c0[0].tag, 1);
        assert_eq!(queues[0].in_flight(), 0);
        assert_eq!(queues[0].submitted(), 1);
        // The device itself is shared: queue 1 reads queue 0's write.
        let c1 = queues[1].poll(16);
        assert_eq!(c1[0].tag, 2);
        assert_eq!(c1[0].data, vec![5u8; 512]);
    }

    #[test]
    fn errors_propagate() {
        let ssd = Arc::new(Ssd::new(4096, 512));
        let aio = AsyncSsd::new(ssd, 1);
        aio.submit(9, SsdOp::Read { addr: 1 << 30, len: 512 });
        let mut done = Vec::new();
        while done.is_empty() {
            done = aio.poll(4);
        }
        assert!(done[0].result.is_err());
    }
}
