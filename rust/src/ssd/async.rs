//! SPDK-like asynchronous submission/completion facade (§7: the DMA
//! thread sends operations to SPDK workers via `spdk_thread_send_msg`;
//! workers submit `spdk_bdev_read/write` and populate the response on
//! completion).
//!
//! Worker threads execute ops against the in-memory [`Ssd`] and post
//! [`Completion`]s to a shared queue the file service polls. With more
//! than one worker, completions genuinely arrive out of submission
//! order, exercising the TailA/TailB/TailC ordered-delivery logic.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

use super::{Ssd, SsdError};
use crate::buf::{BufPool, BufView, PooledBuf};
use crate::fault::{SsdFault, SsdFaultInjector};
use crate::idle::Doorbell;

/// A submitted operation. Buffers travel with the op as refcounted
/// views (the functional analog of pointing the driver at
/// request/response buffer memory — §4.3's zero-copy contract).
#[derive(Debug)]
pub enum SsdOp {
    Read { addr: u64, len: usize },
    /// Write consumes the request buffer by reference, never a copy.
    Write { addr: u64, data: BufView },
}

/// Completion posted by a worker.
#[derive(Debug)]
pub struct Completion {
    /// Caller-chosen tag (e.g. response-buffer slot index).
    pub tag: u64,
    /// Read payload (empty for writes): the buffer the device "DMA'd"
    /// into — pool-backed when a read pool is attached — handed to the
    /// consumer as a view it can reference all the way to the wire.
    pub data: BufView,
    pub result: Result<(), SsdError>,
}

/// One queued operation. `fault` is decided at submit time so the
/// injection stream stays deterministic in submit order even with
/// racing workers. (There is deliberately NO stop sentinel: shutdown
/// is signalled by dropping the submission sender — see
/// [`AsyncSsd`]'s `Drop` for the contract.)
struct Job {
    tag: u64,
    op: SsdOp,
    fault: Option<SsdFault>,
}

/// Execute one op against the device, honoring an injected fault.
/// Returns the completion to post, or `None` for a dropped completion
/// (the op still executed — the *completion* is what got lost).
/// Reads land in a buffer borrowed from `pool` when one is attached
/// (the pre-allocated DMA-able memory of Fig 12); otherwise a plain
/// owned buffer.
fn run_op(
    ssd: &Ssd,
    pool: Option<&BufPool>,
    tag: u64,
    op: SsdOp,
    fault: Option<SsdFault>,
) -> Option<Completion> {
    if fault == Some(SsdFault::Fail) {
        return Some(Completion { tag, data: BufView::empty(), result: Err(SsdError::Injected) });
    }
    let completion = match op {
        SsdOp::Read { addr, len } => {
            let mut buf = match pool {
                Some(p) => p.allocate(len),
                None => PooledBuf::from_vec(vec![0u8; len]),
            };
            let result = ssd.read_into(addr, buf.as_mut_slice());
            // A failed read must NOT ship the buffer: a recycled pool
            // slot still holds a previous request's bytes, and an error
            // completion must never expose cross-request data. Dropping
            // `buf` here returns the slot immediately.
            let data = if result.is_ok() { buf.freeze() } else { BufView::empty() };
            Completion { tag, data, result }
        }
        SsdOp::Write { addr, data } => {
            let result = ssd.write_from(addr, &data);
            Completion { tag, data: BufView::empty(), result }
        }
    };
    if fault == Some(SsdFault::Drop) {
        return None;
    }
    Some(completion)
}

/// Async facade over [`Ssd`] with `workers` SPDK-like worker threads.
///
/// `workers == 0` selects **inline (polled) mode**: operations execute
/// synchronously at submit time on the caller's thread and only the
/// completion queue is deferred. This mirrors SPDK's polled-mode
/// driver and is the right choice on few-core hosts — the perf pass
/// found the worker handoff (mutex + context switch) dominating the
/// single-core profile (EXPERIMENTS.md §Perf L3-3). Completions still
/// flow through `poll()`, so callers exercise the same
/// pending→complete machinery.
pub struct AsyncSsd {
    tx: Option<mpsc::Sender<Job>>,
    /// Inline-mode execution target.
    inline_ssd: Option<Arc<Ssd>>,
    completions: Arc<Mutex<VecDeque<Completion>>>,
    /// Fault-delayed completions: `(polls_remaining, completion)`;
    /// each `poll()` call ages them by one.
    delayed: Arc<Mutex<Vec<(u32, Completion)>>>,
    /// Pool read buffers land in (shared with workers so it can be
    /// attached after spawn; set-once, read lock-free on the op path).
    /// Unset → owned heap buffers per read.
    read_pool: Arc<OnceLock<BufPool>>,
    /// Doorbell rung after a worker posts a completion, so a parked
    /// consumer pump (the file service) wakes to absorb it. Set-once;
    /// unset (and in inline mode, where the submitter IS the poller)
    /// no ring happens.
    waker: Arc<OnceLock<Arc<Doorbell>>>,
    /// Optional fault-injection hook, consulted once per submit.
    faults: Option<SsdFaultInjector>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    /// Queue-depth accounting: ops submitted / completions drained by
    /// the owner of this queue. (A fault-dropped completion is never
    /// polled, so `in_flight` stays elevated — the queue depth a real
    /// driver would see for a lost interrupt.)
    submitted: AtomicU64,
    polled: AtomicU64,
}

impl AsyncSsd {
    /// Inline (polled) mode — see struct docs.
    pub fn new_inline(ssd: Arc<Ssd>) -> Self {
        AsyncSsd {
            tx: None,
            inline_ssd: Some(ssd),
            completions: Arc::new(Mutex::new(VecDeque::new())),
            delayed: Arc::new(Mutex::new(Vec::new())),
            read_pool: Arc::new(OnceLock::new()),
            waker: Arc::new(OnceLock::new()),
            faults: None,
            handles: Vec::new(),
            workers: 0,
            submitted: AtomicU64::new(0),
            polled: AtomicU64::new(0),
        }
    }

    /// Attach a fault injector; every subsequent submit consults it.
    pub fn attach_faults(&mut self, faults: SsdFaultInjector) {
        self.faults = Some(faults);
    }

    /// Attach the pool read completions land in (Fig 12 ①: the SSD DMA
    /// target is pre-allocated DMA-able memory, not a fresh heap
    /// buffer). Shared with worker threads; effective for every
    /// subsequent read. Set-once: the first attach wins, so the op
    /// path reads it lock-free.
    pub fn attach_read_pool(&self, pool: BufPool) {
        let _ = self.read_pool.set(pool);
    }

    /// Attach the doorbell rung when a worker posts a completion (the
    /// completion interrupt of the wake graph): a consumer pump parked
    /// between polls is woken instead of waiting out its bounded park.
    /// Set-once like the read pool; no-op in inline mode, where
    /// completions are queued on the submitting (= polling) thread.
    pub fn attach_waker(&self, waker: Arc<Doorbell>) {
        let _ = self.waker.set(waker);
    }

    /// Per-shard submission queues over one shared device (§7).
    ///
    /// Each returned queue has its own submission channel, its own
    /// completion queue and its own workers (`workers_per_queue == 0`
    /// selects inline polled mode per queue), so shards submitting and
    /// polling concurrently never contend on a shared queue lock — the
    /// only shared structure is the device itself.
    pub fn shard_queues(
        ssd: &Arc<Ssd>,
        queues: usize,
        workers_per_queue: usize,
    ) -> Vec<AsyncSsd> {
        assert!(queues >= 1);
        (0..queues).map(|_| AsyncSsd::new(ssd.clone(), workers_per_queue)).collect()
    }

    pub fn new(ssd: Arc<Ssd>, workers: usize) -> Self {
        if workers == 0 {
            return Self::new_inline(ssd);
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let completions = Arc::new(Mutex::new(VecDeque::new()));
        let delayed = Arc::new(Mutex::new(Vec::new()));
        let read_pool: Arc<OnceLock<BufPool>> = Arc::new(OnceLock::new());
        let waker: Arc<OnceLock<Arc<Doorbell>>> = Arc::new(OnceLock::new());
        let mut handles = Vec::new();
        for _ in 0..workers {
            let rx = rx.clone();
            let ssd = ssd.clone();
            let completions = completions.clone();
            let delayed: Arc<Mutex<Vec<(u32, Completion)>>> = delayed.clone();
            let read_pool = read_pool.clone();
            let waker = waker.clone();
            handles.push(std::thread::spawn(move || loop {
                // The shared receiver mutex is held across this
                // blocking recv — that is fine because shutdown wakes
                // it through the channel itself (sender drop), never
                // by trying to take the mutex.
                let job = { rx.lock().unwrap().recv() };
                match job {
                    Ok(Job { tag, op, fault }) => {
                        let held = matches!(fault, Some(SsdFault::Delay(_)));
                        if let Some(completion) = run_op(&ssd, read_pool.get(), tag, op, fault) {
                            if held {
                                let Some(SsdFault::Delay(polls)) = fault else { unreachable!() };
                                delayed.lock().unwrap().push((polls, completion));
                            } else {
                                completions.lock().unwrap().push_back(completion);
                                // Ring AFTER the push is visible: a
                                // consumer that snapshots its doorbell
                                // before polling can then never sleep
                                // through this completion.
                                if let Some(w) = waker.get() {
                                    w.ring();
                                }
                            }
                        }
                    }
                    // Disconnected: the owner dropped the sender (the
                    // shutdown contract) and every queued op has been
                    // drained — mpsc delivers buffered messages before
                    // reporting disconnect.
                    Err(_) => break,
                }
            }));
        }
        AsyncSsd {
            tx: Some(tx),
            inline_ssd: None,
            completions,
            delayed,
            read_pool,
            waker,
            faults: None,
            handles,
            workers,
            submitted: AtomicU64::new(0),
            polled: AtomicU64::new(0),
        }
    }

    /// Submit an operation with a caller tag; returns immediately in
    /// worker mode, after synchronous execution in inline mode. The
    /// fault injector (if attached) is consulted here, in submit order.
    pub fn submit(&self, tag: u64, op: SsdOp) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let fault = self.faults.as_ref().and_then(|f| f.decide());
        if let Some(ssd) = &self.inline_ssd {
            if let Some(completion) = run_op(ssd, self.read_pool.get(), tag, op, fault) {
                if let Some(SsdFault::Delay(polls)) = fault {
                    self.delayed.lock().unwrap().push((polls, completion));
                } else {
                    self.completions.lock().unwrap().push_back(completion);
                }
            }
            return;
        }
        self.tx.as_ref().unwrap().send(Job { tag, op, fault }).expect("ssd workers alive");
    }

    /// Poll completed operations (drains up to `max`). Each call ages
    /// fault-delayed completions by one poll and releases the expired.
    pub fn poll(&self, max: usize) -> Vec<Completion> {
        // Delayed entries can only exist when an injector is attached;
        // keep the uninstrumented hot path free of the extra lock.
        if self.faults.is_some() {
            let mut d = self.delayed.lock().unwrap();
            if !d.is_empty() {
                let mut q = self.completions.lock().unwrap();
                let mut i = 0;
                while i < d.len() {
                    if d[i].0 <= 1 {
                        q.push_back(d.remove(i).1);
                    } else {
                        d[i].0 -= 1;
                        i += 1;
                    }
                }
            }
        }
        let mut q = self.completions.lock().unwrap();
        let n = q.len().min(max);
        if n > 0 {
            self.polled.fetch_add(n as u64, Ordering::Relaxed);
        }
        q.drain(..n).collect()
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Operations submitted on this queue so far.
    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Ops submitted but whose completions have not been drained yet
    /// (the queue depth a shard sees on its own queue).
    pub fn in_flight(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed) - self.polled.load(Ordering::Relaxed)
    }
}

impl Drop for AsyncSsd {
    /// Shutdown contract (regression: PR 5): dropping the submission
    /// sender is the one and only stop signal. Workers share the
    /// receiver behind a mutex and block in `recv()` while holding it,
    /// so shutdown must arrive *through the channel*, never by
    /// acquiring the mutex: the sender drop wakes the blocked worker
    /// with `Disconnected` immediately, each remaining worker then
    /// takes the lock and observes the same, and `drop`/`remount` can
    /// never hang behind a blocked worker. Queued ops are still
    /// executed first — mpsc delivers buffered messages before
    /// reporting disconnect — so a submitted write is never lost to
    /// shutdown (its completion may be, which is exactly what a
    /// torn-down completion queue means). A queued stop *sentinel*
    /// (the previous design) gave neither guarantee shape: it waited
    /// behind every queued op before waking anyone, and one sentinel
    /// per worker had to drain strictly in order.
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_roundtrip() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 2);
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![42u8; 512].into() });
        // Wait for write completion.
        let mut done = Vec::new();
        while done.is_empty() {
            done = aio.poll(16);
        }
        assert_eq!(done[0].tag, 1);
        assert!(done[0].result.is_ok());

        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        let mut done = Vec::new();
        while done.is_empty() {
            done = aio.poll(16);
        }
        assert_eq!(done[0].tag, 2);
        assert_eq!(done[0].data, vec![42u8; 512]);
    }

    #[test]
    fn many_outstanding_all_complete() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 4);
        let n = 256;
        for i in 0..n {
            aio.submit(i, SsdOp::Write { addr: (i % 128) * 512, data: vec![i as u8; 512].into() });
        }
        let mut tags = Vec::new();
        while tags.len() < n as usize {
            for c in aio.poll(64) {
                assert!(c.result.is_ok());
                tags.push(c.tag);
            }
        }
        tags.sort_unstable();
        assert_eq!(tags, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn inline_mode_same_contract() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd);
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![9u8; 512].into() });
        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        let done = aio.poll(16);
        assert_eq!(done.len(), 2);
        assert_eq!(done[1].data, vec![9u8; 512]);
        assert_eq!(aio.workers(), 0);
    }

    #[test]
    fn shard_queues_are_independent() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let queues = AsyncSsd::shard_queues(&ssd, 3, 0);
        assert_eq!(queues.len(), 3);
        queues[0].submit(1, SsdOp::Write { addr: 0, data: vec![5u8; 512].into() });
        queues[1].submit(2, SsdOp::Read { addr: 0, len: 512 });
        // Completions stay on the queue that submitted them; other
        // queues observe nothing.
        assert!(queues[2].poll(16).is_empty());
        assert_eq!(queues[0].in_flight(), 1);
        let c0 = queues[0].poll(16);
        assert_eq!(c0.len(), 1);
        assert_eq!(c0[0].tag, 1);
        assert_eq!(queues[0].in_flight(), 0);
        assert_eq!(queues[0].submitted(), 1);
        // The device itself is shared: queue 1 reads queue 0's write.
        let c1 = queues[1].poll(16);
        assert_eq!(c1[0].tag, 2);
        assert_eq!(c1[0].data, vec![5u8; 512]);
    }

    #[test]
    fn injected_faults_fail_drop_and_delay() {
        use crate::fault::{FaultConfig, FaultPlane, FaultSite, SsdFaultConfig};
        // fail_p = 1.0: every op errors with Injected.
        let plane = FaultPlane::new(FaultConfig {
            seed: 5,
            ssd: SsdFaultConfig { fail_p: 1.0, ..Default::default() },
            ..Default::default()
        });
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let mut aio = AsyncSsd::new_inline(ssd.clone());
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![7u8; 512].into() });
        let done = aio.poll(4);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].result, Err(SsdError::Injected));
        // The failed write must not have touched the device.
        let mut buf = vec![0xffu8; 512];
        ssd.read_into(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&b| b == 0));

        // drop_p = 1.0: the op executes but the completion is lost.
        let plane = FaultPlane::new(FaultConfig {
            seed: 5,
            ssd: SsdFaultConfig { drop_p: 1.0, ..Default::default() },
            ..Default::default()
        });
        let mut aio = AsyncSsd::new_inline(ssd.clone());
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        aio.submit(2, SsdOp::Write { addr: 0, data: vec![9u8; 512].into() });
        assert!(aio.poll(4).is_empty(), "completion was dropped");
        assert_eq!(aio.in_flight(), 1, "lost completion keeps the op in flight");
        ssd.read_into(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&b| b == 9), "dropped COMPLETION, not the op");

        // delay_p = 1.0 with 3-poll holdback.
        let plane = FaultPlane::new(FaultConfig {
            seed: 5,
            ssd: SsdFaultConfig { delay_p: 1.0, delay_polls: 3, ..Default::default() },
            ..Default::default()
        });
        let mut aio = AsyncSsd::new_inline(ssd);
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        aio.submit(3, SsdOp::Read { addr: 0, len: 512 });
        assert!(aio.poll(4).is_empty());
        assert!(aio.poll(4).is_empty());
        let done = aio.poll(4);
        assert_eq!(done.len(), 1, "released on the delay_polls-th poll");
        assert_eq!(done[0].data, vec![9u8; 512]);
        assert!(done[0].result.is_ok());
    }

    #[test]
    fn worker_mode_honors_injected_faults() {
        use crate::fault::{FaultConfig, FaultPlane, FaultSite, SsdFaultConfig};
        let plane = FaultPlane::new(FaultConfig {
            seed: 11,
            ssd: SsdFaultConfig { fail_p: 1.0, ..Default::default() },
            ..Default::default()
        });
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let mut aio = AsyncSsd::new(ssd, 2);
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        for i in 0..8 {
            aio.submit(i, SsdOp::Read { addr: 0, len: 512 });
        }
        let mut done = Vec::new();
        while done.len() < 8 {
            done.extend(aio.poll(16));
        }
        assert!(done.iter().all(|c| c.result == Err(SsdError::Injected)));
    }

    #[test]
    fn attached_read_pool_backs_completions() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd);
        let pool = BufPool::new(4, 4096);
        aio.attach_read_pool(pool.clone());
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![3u8; 512].into() });
        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        let done = aio.poll(16);
        assert_eq!(done.len(), 2);
        assert_eq!(done[1].data, vec![3u8; 512]);
        let s = pool.stats();
        assert_eq!((s.pool_hits, s.fallbacks), (1, 0), "read buffer came from the slab");
        assert_eq!(pool.in_use(), 1, "completion view holds the slot");
        drop(done);
        assert_eq!(pool.in_use(), 0, "dropping the completion returns it");
    }

    /// The torn-write power cut injects at the device layer, so the
    /// SPDK-like facade surfaces it as failed completions — the shape
    /// the file service's staging machinery turns into ERR responses.
    #[test]
    fn power_cut_propagates_through_async_facade() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd.clone());
        ssd.arm_power_cut(0, 100);
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![1u8; 512].into() });
        aio.submit(2, SsdOp::Read { addr: 0, len: 64 });
        let done = aio.poll(8);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].result, Err(SsdError::PowerLost));
        assert_eq!(done[1].result, Err(SsdError::PowerLost));
        assert!(done[1].data.is_empty(), "failed read must not ship a buffer");
        // After reboot, exactly the torn prefix survived.
        ssd.power_restore();
        let mut buf = vec![0u8; 512];
        ssd.read_into(0, &mut buf).unwrap();
        assert!(buf[..100].iter().all(|&b| b == 1), "torn prefix landed");
        assert!(buf[100..].iter().all(|&b| b == 0), "bytes past the cut never landed");
    }

    /// Regression (PR 5): shutdown must have an explicit wake path for
    /// workers blocked in `recv()` behind the shared receiver mutex —
    /// the sender-drop contract. Idle workers (nothing queued, one of
    /// them asleep inside the lock) must all exit promptly.
    #[test]
    fn drop_wakes_blocked_workers_promptly() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 4);
        // Give the workers time to park in recv() (one holding the
        // receiver mutex, the rest queued on it).
        std::thread::sleep(std::time::Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        drop(aio);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "drop hung behind a blocked worker"
        );
    }

    /// The other half of the contract: ops queued at drop time are
    /// drained before the workers exit (mpsc delivers buffered
    /// messages before reporting disconnect), so a submitted write is
    /// never lost to shutdown.
    #[test]
    fn drop_drains_queued_ops_before_exit() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd.clone(), 1);
        for i in 0..32u64 {
            aio.submit(i, SsdOp::Write { addr: i * 512, data: vec![i as u8 + 1; 512].into() });
        }
        drop(aio); // immediately: most ops are still queued
        let mut buf = vec![0u8; 512];
        for i in 0..32u64 {
            ssd.read_into(i * 512, &mut buf).unwrap();
            assert!(buf.iter().all(|&b| b == i as u8 + 1), "queued write {i} lost to shutdown");
        }
    }

    /// Worker completions ring the attached waker (the completion
    /// interrupt of the wake graph) — and only after the completion is
    /// actually pollable.
    #[test]
    fn worker_completion_rings_attached_waker() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 2);
        let bell = Doorbell::new();
        aio.attach_waker(bell.clone());
        let seen = bell.seq();
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![4u8; 512].into() });
        assert!(bell.wait(seen, std::time::Duration::from_secs(5)), "completion never rang");
        let done = aio.poll(16);
        assert_eq!(done.len(), 1, "ring fired before the completion was pollable");
    }

    #[test]
    fn errors_propagate() {
        let ssd = Arc::new(Ssd::new(4096, 512));
        let aio = AsyncSsd::new(ssd, 1);
        aio.submit(9, SsdOp::Read { addr: 1 << 30, len: 512 });
        let mut done = Vec::new();
        while done.is_empty() {
            done = aio.poll(4);
        }
        assert!(done[0].result.is_err());
        assert!(done[0].data.is_empty(), "failed reads must not ship a buffer");
    }

    /// Regression: an error completion must never expose a recycled
    /// slot's previous contents — the slot returns to the pool instead.
    #[test]
    fn failed_read_returns_slot_without_exposing_stale_bytes() {
        let ssd = Arc::new(Ssd::new(4096, 512));
        let aio = AsyncSsd::new_inline(ssd);
        let pool = BufPool::new(1, 1024);
        aio.attach_read_pool(pool.clone());
        // Warm the single slot with real data, then recycle it.
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![0xAA; 512].into() });
        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        drop(aio.poll(4));
        assert_eq!(pool.available(), 1, "slot recycled with stale 0xAA bytes");
        // Out-of-range read: fails after borrowing the dirty slot.
        aio.submit(3, SsdOp::Read { addr: 1 << 30, len: 512 });
        let done = aio.poll(4);
        assert!(done[0].result.is_err());
        assert!(done[0].data.is_empty(), "stale slot bytes leaked via error completion");
        assert_eq!(pool.in_use(), 0, "failed read's slot went straight home");
    }
}
