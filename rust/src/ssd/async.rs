//! SPDK-like asynchronous submission/completion facade (§7: the DMA
//! thread sends operations to SPDK workers via `spdk_thread_send_msg`;
//! workers submit `spdk_bdev_read/write` and populate the response on
//! completion).
//!
//! Worker threads execute ops against the in-memory [`Ssd`] and post
//! [`Completion`]s to a shared queue the file service polls. With more
//! than one worker, completions genuinely arrive out of submission
//! order, exercising the TailA/TailB/TailC ordered-delivery logic.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

use super::{Ssd, SsdError};
use crate::buf::{BufPool, BufView, PooledBuf};
use crate::fault::{SsdFault, SsdFaultInjector};
use crate::idle::Doorbell;

/// A submitted operation. Buffers travel with the op as refcounted
/// views (the functional analog of pointing the driver at
/// request/response buffer memory — §4.3's zero-copy contract).
#[derive(Debug)]
pub enum SsdOp {
    Read { addr: u64, len: usize },
    /// Write consumes the request buffer by reference, never a copy.
    Write { addr: u64, data: BufView },
}

/// Completion posted by a worker.
#[derive(Debug)]
pub struct Completion {
    /// Caller-chosen tag (e.g. response-buffer slot index).
    pub tag: u64,
    /// Read payload (empty for writes): the buffer the device "DMA'd"
    /// into — pool-backed when a read pool is attached — handed to the
    /// consumer as a view it can reference all the way to the wire.
    pub data: BufView,
    pub result: Result<(), SsdError>,
}

/// One queued operation. `fault` is decided at submit time so the
/// injection stream stays deterministic in submit order even with
/// racing workers. (There is deliberately NO stop sentinel: shutdown
/// is signalled by dropping the submission sender — see
/// [`AsyncSsd`]'s `Drop` for the contract.)
struct JobEntry {
    tag: u64,
    op: SsdOp,
    fault: Option<SsdFault>,
}

/// What travels over the submission channel: a single op, or a whole
/// burst in ONE send. A burst is executed run-to-completion by one
/// worker, which publishes every completion under a single queue lock
/// and rings the doorbell once for the burst — the per-op handoff cost
/// (send + lock + ring) is paid once per burst instead of once per op.
/// Independent bursts still land on different workers, so cross-burst
/// completion reordering (what TailA/TailB/TailC exists for) is still
/// exercised.
enum Job {
    One(JobEntry),
    Burst(Vec<JobEntry>),
}

/// Execute one op against the device, honoring an injected fault.
/// Returns the completion to post, or `None` for a dropped completion
/// (the op still executed — the *completion* is what got lost).
/// Reads land in a buffer borrowed from `pool` when one is attached
/// (the pre-allocated DMA-able memory of Fig 12); otherwise a plain
/// owned buffer.
fn run_op(
    ssd: &Ssd,
    pool: Option<&BufPool>,
    tag: u64,
    op: SsdOp,
    fault: Option<SsdFault>,
) -> Option<Completion> {
    if fault == Some(SsdFault::Fail) {
        return Some(Completion { tag, data: BufView::empty(), result: Err(SsdError::Injected) });
    }
    let completion = match op {
        SsdOp::Read { addr, len } => {
            let mut buf = match pool {
                Some(p) => p.allocate(len),
                None => PooledBuf::from_vec(vec![0u8; len]),
            };
            let result = ssd.read_into(addr, buf.as_mut_slice());
            // A failed read must NOT ship the buffer: a recycled pool
            // slot still holds a previous request's bytes, and an error
            // completion must never expose cross-request data. Dropping
            // `buf` here returns the slot immediately.
            let data = if result.is_ok() { buf.freeze() } else { BufView::empty() };
            Completion { tag, data, result }
        }
        SsdOp::Write { addr, data } => {
            let result = ssd.write_from(addr, &data);
            Completion { tag, data: BufView::empty(), result }
        }
    };
    if fault == Some(SsdFault::Drop) {
        return None;
    }
    Some(completion)
}

/// Publish a burst's completions: ready ones appended to the
/// completion queue under ONE lock acquisition, held (fault-delayed)
/// ones likewise. The emptiness counters are bumped while the lock is
/// still held, strictly before the doorbell ring — so a consumer woken
/// by the ring can never fast-path past completions it was woken for.
fn publish_burst(
    completions: &Mutex<VecDeque<Completion>>,
    comp_len: &AtomicUsize,
    delayed: &Mutex<Vec<(u32, Completion)>>,
    delayed_len: &AtomicUsize,
    waker: Option<&Doorbell>,
    ready: Vec<Completion>,
    held: Vec<(u32, Completion)>,
) {
    if !held.is_empty() {
        let mut d = delayed.lock().unwrap();
        // LINT: relaxed-ok(bump under the delayed mutex, before the ring;
        // proven by ssd::async loom_models — see loom_ssd_fastpath_sound)
        delayed_len.fetch_add(held.len(), Ordering::Relaxed);
        d.extend(held);
    }
    if !ready.is_empty() {
        {
            let mut q = completions.lock().unwrap();
            // LINT: relaxed-ok(bump under the queue mutex, strictly before
            // the SeqCst doorbell ring below: a woken consumer's Relaxed
            // read is ordered by the ring edge — loom_ssd_fastpath_sound)
            comp_len.fetch_add(ready.len(), Ordering::Relaxed);
            q.extend(ready);
        }
        // Ring AFTER the push is visible: a consumer that snapshots
        // its doorbell before polling can then never sleep through
        // this burst. One ring for the whole burst.
        if let Some(w) = waker {
            w.ring();
        }
    }
}

/// Async facade over [`Ssd`] with `workers` SPDK-like worker threads.
///
/// `workers == 0` selects **inline (polled) mode**: operations execute
/// synchronously at submit time on the caller's thread and only the
/// completion queue is deferred. This mirrors SPDK's polled-mode
/// driver and is the right choice on few-core hosts — the perf pass
/// found the worker handoff (mutex + context switch) dominating the
/// single-core profile (EXPERIMENTS.md §Perf L3-3). Completions still
/// flow through `poll()`, so callers exercise the same
/// pending→complete machinery.
pub struct AsyncSsd {
    tx: Option<mpsc::Sender<Job>>,
    /// Inline-mode execution target.
    inline_ssd: Option<Arc<Ssd>>,
    completions: Arc<Mutex<VecDeque<Completion>>>,
    /// Fault-delayed completions: `(polls_remaining, completion)`;
    /// each `poll()` call ages them by one.
    delayed: Arc<Mutex<Vec<(u32, Completion)>>>,
    /// Pool read buffers land in (shared with workers so it can be
    /// attached after spawn; set-once, read lock-free on the op path).
    /// Unset → owned heap buffers per read.
    read_pool: Arc<OnceLock<BufPool>>,
    /// Doorbell rung after a worker posts a completion, so a parked
    /// consumer pump (the file service) wakes to absorb it. Set-once;
    /// unset (and in inline mode, where the submitter IS the poller)
    /// no ring happens.
    waker: Arc<OnceLock<Arc<Doorbell>>>,
    /// Optional fault-injection hook, consulted once per submit.
    faults: Option<SsdFaultInjector>,
    /// Relaxed mirror of `completions.len()`, maintained by every push
    /// and drain site so an idle `poll()` can observe emptiness without
    /// touching the mutex (and so never contends with a worker
    /// mid-publish).
    comp_len: Arc<AtomicUsize>,
    /// Same, for the fault-delayed list: idle polling with an injector
    /// attached but nothing held must not take the delayed lock either.
    delayed_len: Arc<AtomicUsize>,
    /// Times `poll()` actually acquired the completion mutex —
    /// observability for the idle fast path (see CpuLedger test).
    poll_locks: AtomicU64,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    /// Queue-depth accounting: ops submitted / completions drained by
    /// the owner of this queue. (A fault-dropped completion is never
    /// polled, so `in_flight` stays elevated — the queue depth a real
    /// driver would see for a lost interrupt.)
    submitted: AtomicU64,
    polled: AtomicU64,
}

impl AsyncSsd {
    /// Inline (polled) mode — see struct docs.
    pub fn new_inline(ssd: Arc<Ssd>) -> Self {
        AsyncSsd {
            tx: None,
            inline_ssd: Some(ssd),
            completions: Arc::new(Mutex::new(VecDeque::new())),
            delayed: Arc::new(Mutex::new(Vec::new())),
            read_pool: Arc::new(OnceLock::new()),
            waker: Arc::new(OnceLock::new()),
            faults: None,
            comp_len: Arc::new(AtomicUsize::new(0)),
            delayed_len: Arc::new(AtomicUsize::new(0)),
            poll_locks: AtomicU64::new(0),
            handles: Vec::new(),
            workers: 0,
            submitted: AtomicU64::new(0),
            polled: AtomicU64::new(0),
        }
    }

    /// Attach a fault injector; every subsequent submit consults it.
    pub fn attach_faults(&mut self, faults: SsdFaultInjector) {
        self.faults = Some(faults);
    }

    /// Attach the pool read completions land in (Fig 12 ①: the SSD DMA
    /// target is pre-allocated DMA-able memory, not a fresh heap
    /// buffer). Shared with worker threads; effective for every
    /// subsequent read. Set-once: the first attach wins, so the op
    /// path reads it lock-free.
    pub fn attach_read_pool(&self, pool: BufPool) {
        let _ = self.read_pool.set(pool);
    }

    /// Attach the doorbell rung when a worker posts a completion (the
    /// completion interrupt of the wake graph): a consumer pump parked
    /// between polls is woken instead of waiting out its bounded park.
    /// Set-once like the read pool; no-op in inline mode, where
    /// completions are queued on the submitting (= polling) thread.
    pub fn attach_waker(&self, waker: Arc<Doorbell>) {
        let _ = self.waker.set(waker);
    }

    /// Per-shard submission queues over one shared device (§7).
    ///
    /// Each returned queue has its own submission channel, its own
    /// completion queue and its own workers (`workers_per_queue == 0`
    /// selects inline polled mode per queue), so shards submitting and
    /// polling concurrently never contend on a shared queue lock — the
    /// only shared structure is the device itself.
    pub fn shard_queues(
        ssd: &Arc<Ssd>,
        queues: usize,
        workers_per_queue: usize,
    ) -> Vec<AsyncSsd> {
        assert!(queues >= 1);
        (0..queues).map(|_| AsyncSsd::new(ssd.clone(), workers_per_queue)).collect()
    }

    pub fn new(ssd: Arc<Ssd>, workers: usize) -> Self {
        if workers == 0 {
            return Self::new_inline(ssd);
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let completions = Arc::new(Mutex::new(VecDeque::new()));
        let delayed = Arc::new(Mutex::new(Vec::new()));
        let comp_len = Arc::new(AtomicUsize::new(0));
        let delayed_len = Arc::new(AtomicUsize::new(0));
        let read_pool: Arc<OnceLock<BufPool>> = Arc::new(OnceLock::new());
        let waker: Arc<OnceLock<Arc<Doorbell>>> = Arc::new(OnceLock::new());
        let mut handles = Vec::new();
        for _ in 0..workers {
            let rx = rx.clone();
            let ssd = ssd.clone();
            let completions = completions.clone();
            let delayed: Arc<Mutex<Vec<(u32, Completion)>>> = delayed.clone();
            let comp_len = comp_len.clone();
            let delayed_len = delayed_len.clone();
            let read_pool = read_pool.clone();
            let waker = waker.clone();
            handles.push(std::thread::spawn(move || loop {
                // The shared receiver mutex is held across this
                // blocking recv — that is fine because shutdown wakes
                // it through the channel itself (sender drop), never
                // by trying to take the mutex.
                // LINT: recv-ok(worker thread, not a pump loop; unblocked by
                // sender drop on shutdown)
                let job = { rx.lock().unwrap().recv() };
                match job {
                    Ok(Job::One(JobEntry { tag, op, fault })) => {
                        let held = matches!(fault, Some(SsdFault::Delay(_)));
                        if let Some(completion) = run_op(&ssd, read_pool.get(), tag, op, fault) {
                            let (mut ready, mut hold) = (Vec::new(), Vec::new());
                            if held {
                                let Some(SsdFault::Delay(polls)) = fault else { unreachable!() };
                                hold.push((polls, completion));
                            } else {
                                ready.push(completion);
                            }
                            publish_burst(
                                &completions,
                                &comp_len,
                                &delayed,
                                &delayed_len,
                                waker.get().map(|w| w.as_ref()),
                                ready,
                                hold,
                            );
                        }
                    }
                    // Run-to-completion: one worker executes the whole
                    // burst, then publishes every completion under a
                    // single lock with a single doorbell ring.
                    Ok(Job::Burst(entries)) => {
                        let mut ready = Vec::with_capacity(entries.len());
                        let mut hold = Vec::new();
                        for JobEntry { tag, op, fault } in entries {
                            let was_delay = matches!(fault, Some(SsdFault::Delay(_)));
                            if let Some(c) = run_op(&ssd, read_pool.get(), tag, op, fault) {
                                if was_delay {
                                    let Some(SsdFault::Delay(polls)) = fault else {
                                        unreachable!()
                                    };
                                    hold.push((polls, c));
                                } else {
                                    ready.push(c);
                                }
                            }
                        }
                        publish_burst(
                            &completions,
                            &comp_len,
                            &delayed,
                            &delayed_len,
                            waker.get().map(|w| w.as_ref()),
                            ready,
                            hold,
                        );
                    }
                    // Disconnected: the owner dropped the sender (the
                    // shutdown contract) and every queued op has been
                    // drained — mpsc delivers buffered messages before
                    // reporting disconnect.
                    Err(_) => break,
                }
            }));
        }
        AsyncSsd {
            tx: Some(tx),
            inline_ssd: None,
            completions,
            delayed,
            read_pool,
            waker,
            faults: None,
            comp_len,
            delayed_len,
            poll_locks: AtomicU64::new(0),
            handles,
            workers,
            submitted: AtomicU64::new(0),
            polled: AtomicU64::new(0),
        }
    }

    /// Submit an operation with a caller tag; returns immediately in
    /// worker mode, after synchronous execution in inline mode. The
    /// fault injector (if attached) is consulted here, in submit order.
    pub fn submit(&self, tag: u64, op: SsdOp) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let fault = self.faults.as_ref().and_then(|f| f.decide());
        if let Some(ssd) = &self.inline_ssd {
            if let Some(completion) = run_op(ssd, self.read_pool.get(), tag, op, fault) {
                if let Some(SsdFault::Delay(polls)) = fault {
                    let mut d = self.delayed.lock().unwrap();
                    // LINT: relaxed-ok(inline mode: submitter IS the poller,
                    // same-thread program order suffices)
                    self.delayed_len.fetch_add(1, Ordering::Relaxed);
                    d.push((polls, completion));
                } else {
                    let mut q = self.completions.lock().unwrap();
                    // LINT: relaxed-ok(inline mode: submitter IS the poller)
                    self.comp_len.fetch_add(1, Ordering::Relaxed);
                    q.push_back(completion);
                }
            }
            return;
        }
        self.tx
            .as_ref()
            .unwrap()
            .send(Job::One(JobEntry { tag, op, fault }))
            .expect("ssd workers alive");
    }

    /// Submit a whole burst: ONE fault-plane consultation pass (still
    /// per-op, in submit order — the injection stream is byte-identical
    /// to the equivalent `submit` sequence), ONE channel send, and in
    /// worker mode one completion-queue lock + ONE doorbell ring when
    /// the burst completes. Drains `ops` in place so the caller's
    /// buffer (and its capacity) is reusable across bursts.
    pub fn submit_batch(&self, ops: &mut Vec<(u64, SsdOp)>) {
        if ops.is_empty() {
            return;
        }
        self.submitted.fetch_add(ops.len() as u64, Ordering::Relaxed);
        if let Some(ssd) = &self.inline_ssd {
            // Inline mode: execute the burst run-to-completion on the
            // caller's thread, publish under one lock acquisition.
            let mut ready = Vec::with_capacity(ops.len());
            let mut hold = Vec::new();
            for (tag, op) in ops.drain(..) {
                let fault = self.faults.as_ref().and_then(|f| f.decide());
                if let Some(c) = run_op(ssd, self.read_pool.get(), tag, op, fault) {
                    if let Some(SsdFault::Delay(polls)) = fault {
                        hold.push((polls, c));
                    } else {
                        ready.push(c);
                    }
                }
            }
            // No ring in inline mode: the submitter IS the poller.
            publish_burst(
                &self.completions,
                &self.comp_len,
                &self.delayed,
                &self.delayed_len,
                None,
                ready,
                hold,
            );
            return;
        }
        let mut entries = Vec::with_capacity(ops.len());
        for (tag, op) in ops.drain(..) {
            let fault = self.faults.as_ref().and_then(|f| f.decide());
            entries.push(JobEntry { tag, op, fault });
        }
        self.tx.as_ref().unwrap().send(Job::Burst(entries)).expect("ssd workers alive");
    }

    /// Age fault-delayed completions by one poll; expired ones move to
    /// the completion queue in submit order (stable `retain_mut`, O(n)
    /// — the previous `remove(i)` loop shifted the tail per expiry,
    /// O(n²) when many delays expire on the same poll).
    fn age_delayed(&self) {
        let mut d = self.delayed.lock().unwrap();
        if d.is_empty() {
            return;
        }
        let mut q = self.completions.lock().unwrap();
        let mut released = 0usize;
        d.retain_mut(|(polls, c)| {
            if *polls <= 1 {
                let done = std::mem::replace(
                    c,
                    Completion { tag: 0, data: BufView::empty(), result: Ok(()) },
                );
                q.push_back(done);
                released += 1;
                false
            } else {
                *polls -= 1;
                true
            }
        });
        if released > 0 {
            // LINT: relaxed-ok(both mutexes held; only the polling thread
            // calls age_delayed, and its own later reads are program-ordered)
            self.comp_len.fetch_add(released, Ordering::Relaxed);
            self.delayed_len.fetch_sub(released, Ordering::Relaxed);
        }
    }

    /// Poll completed operations (drains up to `max`). Each call ages
    /// fault-delayed completions by one poll and releases the expired.
    pub fn poll(&self, max: usize) -> Vec<Completion> {
        let mut out = Vec::new();
        self.poll_into(&mut out, max);
        out
    }

    /// Buffer-reusing poll: appends up to `max` completions to `out`
    /// and returns how many were appended. Steady-state polling with a
    /// recycled `out` allocates nothing; an *idle* poll (both queues
    /// empty) touches no mutex at all — emptiness is observed through
    /// relaxed counters maintained at every push site, so an idle pump
    /// can never contend with a worker mid-publish. A push that races
    /// this check is missed for one round only: the producer bumps the
    /// counter before ringing the doorbell, and the woken consumer's
    /// next poll sees it.
    pub fn poll_into(&self, out: &mut Vec<Completion>, max: usize) -> usize {
        // Emptiness FAST PATH. Sound under the snapshot-seq-before-scan
        // discipline: a pump snapshots the SeqCst doorbell seq BEFORE
        // these loads, so if a producer's bump (made under the mutex,
        // before its SeqCst ring) is missed here, the ring bumps seq and
        // the pump's wait() returns immediately; the re-poll then sees
        // the counter. Model-checked exhaustively in this file's
        // loom_models: loom_ssd_fastpath_sound proves it,
        // loom_ssd_fastpath_mutation_hangs shows bump-after-ring loses
        // the wakeup.
        // LINT: relaxed-ok(fast path; see soundness argument above)
        if self.delayed_len.load(Ordering::Relaxed) > 0 {
            self.age_delayed();
        }
        // LINT: relaxed-ok(fast path; see soundness argument above)
        if self.comp_len.load(Ordering::Relaxed) == 0 {
            return 0;
        }
        self.poll_locks.fetch_add(1, Ordering::Relaxed);
        let mut q = self.completions.lock().unwrap();
        let n = q.len().min(max);
        if n > 0 {
            self.polled.fetch_add(n as u64, Ordering::Relaxed);
            // LINT: relaxed-ok(drain-side decrement under the queue mutex)
            self.comp_len.fetch_sub(n, Ordering::Relaxed);
            out.extend(q.drain(..n));
        }
        n
    }

    /// Times `poll` acquired the completion mutex (observability for
    /// the idle fast path: an idle pump must not grow this).
    pub fn poll_lock_acquires(&self) -> u64 {
        self.poll_locks.load(Ordering::Relaxed)
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Operations submitted on this queue so far.
    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Ops submitted but whose completions have not been drained yet
    /// (the queue depth a shard sees on its own queue).
    pub fn in_flight(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed) - self.polled.load(Ordering::Relaxed)
    }
}

impl Drop for AsyncSsd {
    /// Shutdown contract (regression: PR 5): dropping the submission
    /// sender is the one and only stop signal. Workers share the
    /// receiver behind a mutex and block in `recv()` while holding it,
    /// so shutdown must arrive *through the channel*, never by
    /// acquiring the mutex: the sender drop wakes the blocked worker
    /// with `Disconnected` immediately, each remaining worker then
    /// takes the lock and observes the same, and `drop`/`remount` can
    /// never hang behind a blocked worker. Queued ops are still
    /// executed first — mpsc delivers buffered messages before
    /// reporting disconnect — so a submitted write is never lost to
    /// shutdown (its completion may be, which is exactly what a
    /// torn-down completion queue means). A queued stop *sentinel*
    /// (the previous design) gave neither guarantee shape: it waited
    /// behind every queued op before waking anyone, and one sentinel
    /// per worker had to drain strictly in order.
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Exhaustive model check of the emptiness fast path (correctness
/// plane; see DESIGN.md). This is a colocated protocol SKELETON, not
/// the full `AsyncSsd`: it reproduces exactly the ordering that makes
/// the fast path sound — Relaxed counter bump strictly before the
/// SeqCst doorbell ring on the producer side, doorbell-seq snapshot
/// strictly before the Relaxed counter scan on the consumer side —
/// with the real [`Doorbell`] in the middle. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_`.
#[cfg(all(loom, test))]
mod loom_models {
    use crate::idle::Doorbell;
    use loom::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    /// Protocol 2 — snapshot-seq-before-scan. The producer publishes
    /// (Relaxed bump) then rings (SeqCst); the consumer snapshots the
    /// doorbell sequence, scans the Relaxed counter, and parks on a
    /// miss. The claim `poll_into` relies on: a missed bump implies the
    /// ring lands after the snapshot, so the park returns immediately
    /// and the re-scan — ordered after a SeqCst read of the advanced
    /// sequence — must see the bump. Every interleaving terminates with
    /// the completion observed; a lost wakeup would deadlock the
    /// unbounded loom park.
    #[test]
    fn loom_ssd_fastpath_sound() {
        loom::model(|| {
            let counter = Arc::new(AtomicUsize::new(0));
            let bell = Doorbell::new();
            let producer = {
                let counter = counter.clone();
                let bell = bell.clone();
                loom::thread::spawn(move || {
                    // publish_burst's order: bump under the (elided)
                    // queue lock, THEN ring.
                    counter.fetch_add(1, Ordering::Relaxed);
                    bell.ring();
                })
            };
            // The consumer pump: snapshot seq BEFORE the scan.
            let mut polls = 0;
            loop {
                let seen = bell.seq();
                if counter.load(Ordering::Relaxed) > 0 {
                    break;
                }
                polls += 1;
                assert!(polls <= 2, "woken pump must see the bump on its re-poll");
                bell.wait(seen, Duration::from_millis(1));
            }
            producer.join().unwrap();
        });
    }

    /// Mutation self-test: flip the producer's program order — ring
    /// BEFORE bump — and the discipline collapses: the consumer can
    /// snapshot the already-rung sequence, scan the not-yet-bumped
    /// counter, and park with no further ring coming. loom must find
    /// that interleaving and report the deadlock; if this stops
    /// panicking, `loom_ssd_fastpath_sound` has gone vacuous.
    #[test]
    #[should_panic]
    fn loom_ssd_fastpath_mutation_hangs() {
        loom::model(|| {
            let counter = Arc::new(AtomicUsize::new(0));
            let bell = Doorbell::new();
            let producer = {
                let counter = counter.clone();
                let bell = bell.clone();
                loom::thread::spawn(move || {
                    bell.ring(); // MUTATION: ring before the bump
                    counter.fetch_add(1, Ordering::Relaxed);
                })
            };
            loop {
                let seen = bell.seq();
                if counter.load(Ordering::Relaxed) > 0 {
                    break;
                }
                bell.wait(seen, Duration::from_millis(1));
            }
            producer.join().unwrap();
        });
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn async_roundtrip() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 2);
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![42u8; 512].into() });
        // Wait for write completion.
        let mut done = Vec::new();
        while done.is_empty() {
            done = aio.poll(16);
        }
        assert_eq!(done[0].tag, 1);
        assert!(done[0].result.is_ok());

        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        let mut done = Vec::new();
        while done.is_empty() {
            done = aio.poll(16);
        }
        assert_eq!(done[0].tag, 2);
        assert_eq!(done[0].data, vec![42u8; 512]);
    }

    #[test]
    fn many_outstanding_all_complete() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 4);
        let n = 256;
        for i in 0..n {
            aio.submit(i, SsdOp::Write { addr: (i % 128) * 512, data: vec![i as u8; 512].into() });
        }
        let mut tags = Vec::new();
        while tags.len() < n as usize {
            for c in aio.poll(64) {
                assert!(c.result.is_ok());
                tags.push(c.tag);
            }
        }
        tags.sort_unstable();
        assert_eq!(tags, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn inline_mode_same_contract() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd);
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![9u8; 512].into() });
        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        let done = aio.poll(16);
        assert_eq!(done.len(), 2);
        assert_eq!(done[1].data, vec![9u8; 512]);
        assert_eq!(aio.workers(), 0);
    }

    #[test]
    fn shard_queues_are_independent() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let queues = AsyncSsd::shard_queues(&ssd, 3, 0);
        assert_eq!(queues.len(), 3);
        queues[0].submit(1, SsdOp::Write { addr: 0, data: vec![5u8; 512].into() });
        queues[1].submit(2, SsdOp::Read { addr: 0, len: 512 });
        // Completions stay on the queue that submitted them; other
        // queues observe nothing.
        assert!(queues[2].poll(16).is_empty());
        assert_eq!(queues[0].in_flight(), 1);
        let c0 = queues[0].poll(16);
        assert_eq!(c0.len(), 1);
        assert_eq!(c0[0].tag, 1);
        assert_eq!(queues[0].in_flight(), 0);
        assert_eq!(queues[0].submitted(), 1);
        // The device itself is shared: queue 1 reads queue 0's write.
        let c1 = queues[1].poll(16);
        assert_eq!(c1[0].tag, 2);
        assert_eq!(c1[0].data, vec![5u8; 512]);
    }

    #[test]
    fn injected_faults_fail_drop_and_delay() {
        use crate::fault::{FaultConfig, FaultPlane, FaultSite, SsdFaultConfig};
        // fail_p = 1.0: every op errors with Injected.
        let plane = FaultPlane::new(FaultConfig {
            seed: 5,
            ssd: SsdFaultConfig { fail_p: 1.0, ..Default::default() },
            ..Default::default()
        });
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let mut aio = AsyncSsd::new_inline(ssd.clone());
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![7u8; 512].into() });
        let done = aio.poll(4);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].result, Err(SsdError::Injected));
        // The failed write must not have touched the device.
        let mut buf = vec![0xffu8; 512];
        ssd.read_into(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&b| b == 0));

        // drop_p = 1.0: the op executes but the completion is lost.
        let plane = FaultPlane::new(FaultConfig {
            seed: 5,
            ssd: SsdFaultConfig { drop_p: 1.0, ..Default::default() },
            ..Default::default()
        });
        let mut aio = AsyncSsd::new_inline(ssd.clone());
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        aio.submit(2, SsdOp::Write { addr: 0, data: vec![9u8; 512].into() });
        assert!(aio.poll(4).is_empty(), "completion was dropped");
        assert_eq!(aio.in_flight(), 1, "lost completion keeps the op in flight");
        ssd.read_into(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&b| b == 9), "dropped COMPLETION, not the op");

        // delay_p = 1.0 with 3-poll holdback.
        let plane = FaultPlane::new(FaultConfig {
            seed: 5,
            ssd: SsdFaultConfig { delay_p: 1.0, delay_polls: 3, ..Default::default() },
            ..Default::default()
        });
        let mut aio = AsyncSsd::new_inline(ssd);
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        aio.submit(3, SsdOp::Read { addr: 0, len: 512 });
        assert!(aio.poll(4).is_empty());
        assert!(aio.poll(4).is_empty());
        let done = aio.poll(4);
        assert_eq!(done.len(), 1, "released on the delay_polls-th poll");
        assert_eq!(done[0].data, vec![9u8; 512]);
        assert!(done[0].result.is_ok());
    }

    #[test]
    fn worker_mode_honors_injected_faults() {
        use crate::fault::{FaultConfig, FaultPlane, FaultSite, SsdFaultConfig};
        let plane = FaultPlane::new(FaultConfig {
            seed: 11,
            ssd: SsdFaultConfig { fail_p: 1.0, ..Default::default() },
            ..Default::default()
        });
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let mut aio = AsyncSsd::new(ssd, 2);
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        for i in 0..8 {
            aio.submit(i, SsdOp::Read { addr: 0, len: 512 });
        }
        let mut done = Vec::new();
        while done.len() < 8 {
            done.extend(aio.poll(16));
        }
        assert!(done.iter().all(|c| c.result == Err(SsdError::Injected)));
    }

    #[test]
    fn attached_read_pool_backs_completions() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd);
        let pool = BufPool::new(4, 4096);
        aio.attach_read_pool(pool.clone());
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![3u8; 512].into() });
        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        let done = aio.poll(16);
        assert_eq!(done.len(), 2);
        assert_eq!(done[1].data, vec![3u8; 512]);
        let s = pool.stats();
        assert_eq!((s.pool_hits, s.fallbacks), (1, 0), "read buffer came from the slab");
        assert_eq!(pool.in_use(), 1, "completion view holds the slot");
        drop(done);
        assert_eq!(pool.in_use(), 0, "dropping the completion returns it");
    }

    /// The torn-write power cut injects at the device layer, so the
    /// SPDK-like facade surfaces it as failed completions — the shape
    /// the file service's staging machinery turns into ERR responses.
    #[test]
    fn power_cut_propagates_through_async_facade() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd.clone());
        ssd.arm_power_cut(0, 100);
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![1u8; 512].into() });
        aio.submit(2, SsdOp::Read { addr: 0, len: 64 });
        let done = aio.poll(8);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].result, Err(SsdError::PowerLost));
        assert_eq!(done[1].result, Err(SsdError::PowerLost));
        assert!(done[1].data.is_empty(), "failed read must not ship a buffer");
        // After reboot, exactly the torn prefix survived.
        ssd.power_restore();
        let mut buf = vec![0u8; 512];
        ssd.read_into(0, &mut buf).unwrap();
        assert!(buf[..100].iter().all(|&b| b == 1), "torn prefix landed");
        assert!(buf[100..].iter().all(|&b| b == 0), "bytes past the cut never landed");
    }

    /// Regression (PR 5): shutdown must have an explicit wake path for
    /// workers blocked in `recv()` behind the shared receiver mutex —
    /// the sender-drop contract. Idle workers (nothing queued, one of
    /// them asleep inside the lock) must all exit promptly.
    #[test]
    fn drop_wakes_blocked_workers_promptly() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 4);
        // Give the workers time to park in recv() (one holding the
        // receiver mutex, the rest queued on it).
        std::thread::sleep(std::time::Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        drop(aio);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "drop hung behind a blocked worker"
        );
    }

    /// The other half of the contract: ops queued at drop time are
    /// drained before the workers exit (mpsc delivers buffered
    /// messages before reporting disconnect), so a submitted write is
    /// never lost to shutdown.
    #[test]
    fn drop_drains_queued_ops_before_exit() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd.clone(), 1);
        for i in 0..32u64 {
            aio.submit(i, SsdOp::Write { addr: i * 512, data: vec![i as u8 + 1; 512].into() });
        }
        drop(aio); // immediately: most ops are still queued
        let mut buf = vec![0u8; 512];
        for i in 0..32u64 {
            ssd.read_into(i * 512, &mut buf).unwrap();
            assert!(buf.iter().all(|&b| b == i as u8 + 1), "queued write {i} lost to shutdown");
        }
    }

    /// Worker completions ring the attached waker (the completion
    /// interrupt of the wake graph) — and only after the completion is
    /// actually pollable.
    #[test]
    fn worker_completion_rings_attached_waker() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 2);
        let bell = Doorbell::new();
        aio.attach_waker(bell.clone());
        let seen = bell.seq();
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![4u8; 512].into() });
        assert!(bell.wait(seen, std::time::Duration::from_secs(5)), "completion never rang");
        let done = aio.poll(16);
        assert_eq!(done.len(), 1, "ring fired before the completion was pollable");
    }

    #[test]
    fn errors_propagate() {
        let ssd = Arc::new(Ssd::new(4096, 512));
        let aio = AsyncSsd::new(ssd, 1);
        aio.submit(9, SsdOp::Read { addr: 1 << 30, len: 512 });
        let mut done = Vec::new();
        while done.is_empty() {
            done = aio.poll(4);
        }
        assert!(done[0].result.is_err());
        assert!(done[0].data.is_empty(), "failed reads must not ship a buffer");
    }

    /// Tentpole: a batched submit is ONE channel send and, in worker
    /// mode, ONE doorbell ring for the whole burst — not one per op.
    #[test]
    fn submit_batch_rings_once_per_burst() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 2);
        let bell = Doorbell::new();
        aio.attach_waker(bell.clone());
        let seen = bell.seq();
        let mut ops: Vec<(u64, SsdOp)> = (0..16u64)
            .map(|i| (i, SsdOp::Write { addr: i * 512, data: vec![i as u8; 512].into() }))
            .collect();
        aio.submit_batch(&mut ops);
        assert!(ops.is_empty(), "batch drained in place");
        let mut done = Vec::new();
        while done.len() < 16 {
            aio.poll_into(&mut done, 64);
        }
        let mut tags: Vec<u64> = done.iter().map(|c| c.tag).collect();
        tags.sort_unstable();
        assert_eq!(tags, (0..16).collect::<Vec<_>>());
        assert!(done.iter().all(|c| c.result.is_ok()));
        assert_eq!(bell.seq() - seen, 1, "one ring for the whole burst");
        assert_eq!(aio.in_flight(), 0);
    }

    /// Satellite (crash-matrix accounting): the device write trace —
    /// what `arm_power_cut` crash points are enumerated from — must see
    /// every write of a `submit_batch` burst, in submission order. The
    /// batched submit path postdates the original trace plumbing; a
    /// burst write missing from the trace would be a crash point the
    /// matrix silently never tests.
    #[test]
    fn batched_writes_all_appear_in_trace_in_submission_order() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd.clone());
        ssd.start_write_trace();
        // Mix a single submit between two bursts: the trace must be the
        // exact submission-order concatenation.
        let mut burst1: Vec<(u64, SsdOp)> = (0..4u64)
            .map(|i| (i, SsdOp::Write { addr: i * 512, data: vec![1u8; 100].into() }))
            .collect();
        aio.submit_batch(&mut burst1);
        aio.submit(99, SsdOp::Write { addr: 8192, data: vec![2u8; 7].into() });
        // Reads must not pollute the write trace.
        aio.submit(98, SsdOp::Read { addr: 0, len: 64 });
        let mut burst2: Vec<(u64, SsdOp)> = (0..3u64)
            .map(|i| (100 + i, SsdOp::Write { addr: 16384 + i * 512, data: vec![3u8; 50].into() }))
            .collect();
        aio.submit_batch(&mut burst2);
        let trace = ssd.take_write_trace();
        let expect: Vec<(u64, usize)> = vec![
            (0, 100),
            (512, 100),
            (1024, 100),
            (1536, 100),
            (8192, 7),
            (16384, 50),
            (16896, 50),
            (17408, 50),
        ];
        assert_eq!(trace, expect, "every batched write traced, in submission order");
        while aio.poll(64).len() < 8 {}
    }

    /// Satellite: a cut index landing *inside* a batch tears exactly
    /// that write — the crash matrix's (write index, byte prefix)
    /// coordinates are valid inside bursts, not just between them.
    #[test]
    fn power_cut_inside_a_batch_tears_the_indexed_write() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd.clone());
        // Burst of 4 writes; cut write index 2 at 5 bytes.
        ssd.arm_power_cut(2, 5);
        let mut ops: Vec<(u64, SsdOp)> = (0..4u64)
            .map(|i| (i, SsdOp::Write { addr: i * 512, data: vec![(i + 1) as u8; 64].into() }))
            .collect();
        aio.submit_batch(&mut ops);
        let mut done = aio.poll(16);
        done.sort_by_key(|c| c.tag);
        assert_eq!(done.len(), 4);
        assert!(done[0].result.is_ok());
        assert!(done[1].result.is_ok());
        assert_eq!(done[2].result, Err(SsdError::PowerLost), "cut write errors");
        assert_eq!(done[3].result, Err(SsdError::PowerLost), "device dead after the cut");
        ssd.power_restore();
        let mut buf = [0u8; 64];
        ssd.read_into(512, &mut buf).unwrap();
        assert_eq!(buf, [2u8; 64], "write before the cut fully landed");
        ssd.read_into(1024, &mut buf).unwrap();
        assert_eq!(&buf[..5], &[3u8; 5]);
        assert!(buf[5..].iter().all(|&b| b == 0), "torn prefix only");
        ssd.read_into(1536, &mut buf).unwrap();
        assert!(buf.iter().all(|&b| b == 0), "write after the cut never landed");
    }

    /// `poll_into` appends into the caller's buffer and reports the
    /// count — steady-state polling with a recycled Vec allocates
    /// nothing and drops nothing.
    #[test]
    fn poll_into_reuses_caller_buffer() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd);
        let mut buf = Vec::with_capacity(8);
        for round in 0..4u64 {
            aio.submit(round, SsdOp::Write { addr: 0, data: vec![1u8; 512].into() });
            buf.clear();
            let n = aio.poll_into(&mut buf, 16);
            assert_eq!(n, 1);
            assert_eq!(buf[0].tag, round);
            assert!(buf.capacity() >= 8, "capacity must survive reuse");
        }
    }

    /// Satellite: many delayed completions expiring on the same poll
    /// must all release on that poll, in submit order (the old
    /// `remove(i)` loop was O(n²); the stable `retain_mut` pass keeps
    /// order and releases in one sweep).
    #[test]
    fn mass_delay_expiry_releases_in_submit_order() {
        use crate::fault::{FaultConfig, FaultPlane, FaultSite, SsdFaultConfig};
        let plane = FaultPlane::new(FaultConfig {
            seed: 7,
            ssd: SsdFaultConfig { delay_p: 1.0, delay_polls: 2, ..Default::default() },
            ..Default::default()
        });
        let ssd = Arc::new(Ssd::new(1 << 22, 512));
        let mut aio = AsyncSsd::new_inline(ssd);
        aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(0)));
        plane.arm_ssd();
        let n = 4096u64;
        let mut ops: Vec<(u64, SsdOp)> =
            (0..n).map(|i| (i, SsdOp::Read { addr: 0, len: 64 })).collect();
        aio.submit_batch(&mut ops);
        assert!(aio.poll(usize::MAX).is_empty(), "all held for one more poll");
        let done = aio.poll(usize::MAX);
        assert_eq!(done.len() as u64, n, "every delayed completion released together");
        let tags: Vec<u64> = done.iter().map(|c| c.tag).collect();
        assert_eq!(tags, (0..n).collect::<Vec<_>>(), "release preserves submit order");
    }

    /// Satellite: an idle poll must not touch the completion mutex.
    /// The relaxed emptiness counter short-circuits before any lock,
    /// so idle polling cannot contend with a worker mid-publish — here
    /// a thread pins the completion mutex for 300ms while a CpuLedger
    /// meters 10k idle polls, which must all return without blocking.
    #[test]
    fn idle_poll_skips_completion_lock() {
        use crate::metrics::CpuLedger;
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new_inline(ssd);
        // Baseline: a non-empty poll takes the lock exactly once.
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![2u8; 512].into() });
        assert_eq!(aio.poll(16).len(), 1);
        let locks_after_drain = aio.poll_lock_acquires();
        assert_eq!(locks_after_drain, 1);

        let q = aio.completions.clone();
        let (locked_tx, locked_rx) = mpsc::channel();
        let holder = std::thread::spawn(move || {
            let _g = q.lock().unwrap();
            locked_tx.send(()).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(300));
        });
        locked_rx.recv().unwrap();
        let ledger = CpuLedger::new();
        let t0 = std::time::Instant::now();
        let mut buf = Vec::new();
        for _ in 0..10_000 {
            assert_eq!(aio.poll_into(&mut buf, 64), 0);
            ledger.iteration(false);
        }
        ledger.add_busy(t0.elapsed());
        holder.join().unwrap();
        let snap = ledger.snapshot();
        assert_eq!(snap.empty_polls, 10_000);
        assert!(
            snap.busy_ns < 200_000_000,
            "idle polling contended the held completion lock ({}ns busy)",
            snap.busy_ns
        );
        assert_eq!(
            aio.poll_lock_acquires(),
            locks_after_drain,
            "idle polls must not acquire the completion mutex"
        );
    }

    /// Regression (correctness plane): the Relaxed emptiness fast path
    /// must never make a woken pump poll-and-miss. A pump that
    /// snapshots the doorbell seq before scanning and is then woken by
    /// the ring must observe the completion on its VERY NEXT
    /// `poll_into` — the producer bumps `comp_len` before its SeqCst
    /// ring, and the pump's SeqCst read of the advanced sequence orders
    /// the Relaxed counter read after the bump
    /// (`loom_ssd_fastpath_sound` proves this exhaustively; this test
    /// pins the real `AsyncSsd` to the modeled discipline).
    #[test]
    fn woken_poll_sees_completion_without_retry() {
        let ssd = Arc::new(Ssd::new(1 << 20, 512));
        let aio = AsyncSsd::new(ssd, 2);
        let bell = Doorbell::new();
        aio.attach_waker(bell.clone());
        let mut out = Vec::new();
        for round in 0..200u64 {
            // Pump discipline: snapshot, scan (empty), park, re-poll.
            let seen = bell.seq();
            out.clear();
            assert_eq!(aio.poll_into(&mut out, 16), 0, "round {round}: queue not drained");
            aio.submit(round, SsdOp::Write { addr: 0, data: vec![1u8; 512].into() });
            assert!(bell.wait(seen, std::time::Duration::from_secs(5)));
            assert_eq!(
                aio.poll_into(&mut out, 16),
                1,
                "round {round}: woken pump fast-pathed past its completion"
            );
            assert_eq!(out[0].tag, round);
        }
    }

    /// Regression: an error completion must never expose a recycled
    /// slot's previous contents — the slot returns to the pool instead.
    #[test]
    fn failed_read_returns_slot_without_exposing_stale_bytes() {
        let ssd = Arc::new(Ssd::new(4096, 512));
        let aio = AsyncSsd::new_inline(ssd);
        let pool = BufPool::new(1, 1024);
        aio.attach_read_pool(pool.clone());
        // Warm the single slot with real data, then recycle it.
        aio.submit(1, SsdOp::Write { addr: 0, data: vec![0xAA; 512].into() });
        aio.submit(2, SsdOp::Read { addr: 0, len: 512 });
        drop(aio.poll(4));
        assert_eq!(pool.available(), 1, "slot recycled with stale 0xAA bytes");
        // Out-of-range read: fails after borrowing the dirty slot.
        aio.submit(3, SsdOp::Read { addr: 1 << 30, len: 512 });
        let done = aio.poll(4);
        assert!(done[0].result.is_err());
        assert!(done[0].data.is_empty(), "stale slot bytes leaked via error completion");
        assert_eq!(pool.in_use(), 0, "failed read's slot went straight home");
    }
}
