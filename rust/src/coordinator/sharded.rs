//! The sharded functional data plane (§7): N share-nothing shards, one
//! OS thread each, RSS-steered.
//!
//! The paper's scaling claim — "the traffic director can direct
//! 6.4 Gbps with a single DPU core and, due to RSS, scale linearly when
//! more cores are added" — rests on the data path being replicated per
//! core with nothing shared on the packet path. [`ShardedServer`] is
//! that deployment for the functional plane:
//!
//! * **Steering** — every client packet batch is routed to
//!   `rss_core(tuple, N)`; the hash is symmetric, so both directions of
//!   a connection and its split host connection land on the same shard
//!   and no connection state ever crosses a shard boundary.
//! * **Per-shard data path** — each shard owns a [`DirectorShard`]
//!   (per-flow split-TCP PEPs + the colocated [`OffloadEngine`] with
//!   its own context ring and mem-pool partition), a private SSD
//!   submission queue ([`crate::ssd::AsyncSsd::shard_queues`]), per-flow
//!   host-side
//!   endpoints of the split connection, and its own host-application
//!   instance whose poll group the (single) DPU file service drains
//!   round-robin alongside every other shard's group.
//! * **Shared, deliberately** — the SSD device, the DPU file system
//!   mapping, and the cache table (§6.1) are the read-mostly structures
//!   the paper also shares across cores.
//!
//! [`super::DisaggregatedServer`] is the N = 1, single-flow,
//! synchronous special case of this design.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{host_exchange, ClientConn, StorageServer, StorageServerConfig};
use crate::apps::HostApp;
use crate::director::{
    rss_core, AppSignature, Burst, DirectorOut, DirectorShard, DirectorShardStats,
    TenantPlaneConfig,
};
use crate::fault::{FaultPlane, FaultSite};
use crate::idle::{IdleGovernor, IdlePolicy, IdleRecv};
use crate::metrics::{
    merge_tenant_tables, CpuLedger, CpuStats, LatencyHistogram, LatencySnapshot, LatencyStats,
    TenantCounters,
};
use crate::net::tcp::{Segment, TcpEndpoint};
use crate::net::FiveTuple;
use crate::offload::{OffloadEngine, OffloadEngineConfig, OffloadLogic};
use crate::proto::{framing, NetMsg, NetResp};

/// One routed batch of wire segments.
pub type PacketBatch = (FiveTuple, Vec<Segment>);

/// Build options for the sharded server.
#[derive(Clone)]
pub struct ShardedServerConfig {
    /// Number of DPU cores to shard the data plane across.
    pub shards: usize,
    /// Storage-path build options (one storage path, shared).
    pub server: StorageServerConfig,
    /// Whole-DPU offload-engine budget; partitioned across shards with
    /// [`OffloadEngineConfig::per_shard`].
    pub engine_total: OffloadEngineConfig,
    /// SPDK-like workers per shard SSD queue (0 = inline polled mode,
    /// the right choice when shards already have a thread each).
    pub queue_workers: usize,
    /// Optional fault plane: when set, each shard's SSD queue gets a
    /// seeded fault injector ([`FaultSite::SsdQueue`]) and
    /// [`ShardedServer::set_engine_failed`] becomes operative.
    pub faults: Option<Arc<FaultPlane>>,
    /// Shard-pump idle discipline: `Poll` busy-polls (one core per
    /// shard even when idle), `Adaptive` (default) climbs the
    /// spin→yield→park ladder, parking on the shard's input channel
    /// when its engine has nothing in flight — a send is itself the
    /// wake, so nothing can be lost. (The file service's own policy is
    /// configured on `server.service.idle`.)
    pub idle: IdlePolicy,
    /// Maximum input batches a shard pump drains into one [`Burst`]
    /// before servicing it (the batch-pipeline knob; `dds serve
    /// --burst`). Larger bursts amortize more per-record bookkeeping
    /// per pass but add queueing delay under saturation; 64 matches the
    /// pre-burst loop bound and keeps worst-case added latency ≈ one
    /// burst service time. Clamped to ≥ 1.
    pub burst: usize,
    /// Multi-tenant QoS knobs (token-bucket rate, pending bound, flow
    /// cap, idle-flow TTL, fair-drain weights). Installed on every
    /// shard; the defaults impose no limits and keep the packet path
    /// clock-free.
    pub tenants: TenantPlaneConfig,
}

impl Default for ShardedServerConfig {
    fn default() -> Self {
        ShardedServerConfig {
            shards: 1,
            server: StorageServerConfig::default(),
            engine_total: OffloadEngineConfig::default(),
            queue_workers: 0,
            faults: None,
            idle: IdlePolicy::default(),
            burst: 64,
            tenants: TenantPlaneConfig::default(),
        }
    }
}

/// Host-side terminus of one flow's split connection (connection 2 of
/// the PEP), shard-local.
struct HostConn {
    ep: TcpEndpoint,
    rx: framing::StreamBuf,
}

impl HostConn {
    fn new() -> Self {
        HostConn { ep: TcpEndpoint::new(), rx: framing::StreamBuf::new() }
    }
}

/// Lock-free published counters of one shard (written by the shard
/// thread, read by anyone holding the server).
#[derive(Default)]
pub struct ShardStats {
    flows: AtomicU64,
    flows_created: AtomicU64,
    flows_closed: AtomicU64,
    msgs_in: AtomicU64,
    reqs_offloaded: AtomicU64,
    reqs_to_host: AtomicU64,
    forwarded_packets: AtomicU64,
    reqs_failed_over: AtomicU64,
    reqs_timed_out: AtomicU64,
}

impl ShardStats {
    fn publish(&self, s: &DirectorShardStats) {
        self.flows.store(s.flows, Ordering::Relaxed);
        self.flows_created.store(s.flows_created, Ordering::Relaxed);
        self.flows_closed.store(s.flows_closed, Ordering::Relaxed);
        self.msgs_in.store(s.msgs_in, Ordering::Relaxed);
        self.reqs_offloaded.store(s.reqs_offloaded, Ordering::Relaxed);
        self.reqs_to_host.store(s.reqs_to_host, Ordering::Relaxed);
        self.forwarded_packets.store(s.forwarded_packets, Ordering::Relaxed);
        self.reqs_failed_over.store(s.reqs_failed_over, Ordering::Relaxed);
        self.reqs_timed_out.store(s.reqs_timed_out, Ordering::Relaxed);
    }

    fn snapshot(&self, shard: usize) -> DirectorShardStats {
        DirectorShardStats {
            shard,
            flows: self.flows.load(Ordering::Relaxed),
            flows_created: self.flows_created.load(Ordering::Relaxed),
            flows_closed: self.flows_closed.load(Ordering::Relaxed),
            msgs_in: self.msgs_in.load(Ordering::Relaxed),
            reqs_offloaded: self.reqs_offloaded.load(Ordering::Relaxed),
            reqs_to_host: self.reqs_to_host.load(Ordering::Relaxed),
            forwarded_packets: self.forwarded_packets.load(Ordering::Relaxed),
            reqs_failed_over: self.reqs_failed_over.load(Ordering::Relaxed),
            reqs_timed_out: self.reqs_timed_out.load(Ordering::Relaxed),
        }
    }
}

/// One shard's complete data path: the DPU side ([`DirectorShard`]) plus
/// the host side of its split connections and its host-app instance.
/// Runs synchronously; [`ShardedServer`] gives each one a thread.
struct Shard<A: HostApp> {
    director: DirectorShard,
    app: A,
    host_conns: HashMap<FiveTuple, HostConn>,
    stats: Arc<ShardStats>,
    /// Engine failure injection, set by the owner thread-safely and
    /// applied by the shard thread at its next iteration.
    fail_flag: Arc<AtomicBool>,
    /// Reused scratch for the decode/service stage's outputs (capacity
    /// survives across bursts — steady-state servicing allocates no
    /// carrier Vecs).
    douts: Vec<(FiveTuple, DirectorOut)>,
    /// Reused scratch for the completion-drain stage.
    pumped: Vec<(FiveTuple, DirectorOut)>,
    /// Per-tenant counter table published for cross-thread readers
    /// (`ShardedServer::tenant_stats`, the control plane).
    tenant_pub: Arc<Mutex<Vec<TenantCounters>>>,
}

/// Flow-table slots an idle sweep examines per poll pass: with the
/// persistent cursor this bounds per-iteration eviction work while a
/// 10k-flow table still cycles completely in a few hundred passes.
const EVICT_SCAN_PER_POLL: usize = 32;

impl<A: HostApp> Shard<A> {
    /// Offloaded reads in flight on this shard's engine: while any are
    /// outstanding the pump must keep polling (completions have no
    /// doorbell into the shard loop), so it naps instead of parking.
    fn in_flight(&self) -> u64 {
        self.director.engine().outstanding()
    }

    /// Apply a pending engine-failure injection (idempotent).
    fn sync_fault_flag(&mut self) {
        let want = self.fail_flag.load(Ordering::Relaxed);
        if want != self.director.engine_failed() {
            self.director.set_engine_failed(want);
        }
    }
    /// Run one whole [`Burst`] through the staged pipeline: fault-flag
    /// sync, decode/service (director + engine), host exchange, late
    /// completions, stats publish — each stage once per burst, not once
    /// per batch. (§5.1 stage-1 misses are counted inside the service
    /// stage and forwarded outside the model: no PEP, no host
    /// connection, NO per-flow state of any kind, so a port scan can't
    /// grow shard memory.)
    fn step_burst(&mut self, burst: &mut Burst, out: &mut Vec<PacketBatch>) {
        if burst.is_empty() {
            return;
        }
        self.sync_fault_flag();
        let mut douts = std::mem::take(&mut self.douts);
        self.director.service_burst(burst, &mut douts);
        for (tuple, dout) in douts.drain(..) {
            let mut to_client = dout.to_client;
            self.pump_flow_host(&tuple, dout.to_host, &mut to_client);
            if !to_client.is_empty() {
                out.push((tuple, to_client));
            }
        }
        self.douts = douts;
        self.drain_completions(out);
        self.publish_stats();
    }

    /// Poll for late engine completions (async SSD queues) and run one
    /// idle-flow sweep increment.
    fn poll(&mut self, out: &mut Vec<PacketBatch>) {
        self.sync_fault_flag();
        self.drain_completions(out);
        // Idle-flow eviction: incremental, and only when there are
        // flows at all (an idle shard with an empty table does no clock
        // reads here). Evicted flows drop their host-side connection
        // state too — otherwise a churned flow population leaks
        // `HostConn`s even after the director forgets the flow.
        if self.director.num_flows() > 0 {
            for tuple in self.director.evict_idle_flows(Instant::now(), EVICT_SCAN_PER_POLL) {
                self.host_conns.remove(&tuple);
            }
        }
        self.publish_stats();
    }

    fn drain_completions(&mut self, out: &mut Vec<PacketBatch>) {
        let mut pumped = std::mem::take(&mut self.pumped);
        self.director.pump_completions_into(&mut pumped);
        for (t, o) in pumped.drain(..) {
            let mut to_client = o.to_client;
            self.pump_flow_host(&t, o.to_host, &mut to_client);
            if !to_client.is_empty() {
                out.push((t, to_client));
            }
        }
        self.pumped = pumped;
    }

    /// Pump one flow's split host connection to quiescence (the shard
    /// analog of `DisaggregatedServer::pump_host`).
    fn pump_flow_host(
        &mut self,
        tuple: &FiveTuple,
        mut to_host: Vec<Segment>,
        to_client: &mut Vec<Segment>,
    ) {
        while !to_host.is_empty() {
            let conn = self.host_conns.entry(*tuple).or_insert_with(HostConn::new);
            let back_to_dpu =
                host_exchange(&mut self.app, &mut conn.ep, &mut conn.rx, &to_host);
            let o = self.director.on_host_packets(tuple, back_to_dpu);
            to_client.extend(o.to_client);
            to_host = o.to_host;
        }
    }

    fn publish_stats(&self) {
        self.stats.publish(&self.director.stats());
        // The tenant table is tiny (one row per tenant) and the mutex
        // is uncontended (readers only at snapshot time); the buffer is
        // reused, so steady-state publishing allocates nothing.
        self.director.publish_tenant_counters(&mut self.tenant_pub.lock().unwrap());
    }
}

/// Flush gathered responses to the output queue. Returns false when
/// the receiver is gone (the pump should exit). The ONE flush used by
/// the normal path, the wake path and the shutdown drain, so delivery
/// behavior cannot diverge between them.
fn flush_outs(outs: &mut Vec<PacketBatch>, tx: &mpsc::Sender<PacketBatch>) -> bool {
    for o in outs.drain(..) {
        if tx.send(o).is_err() {
            return false;
        }
    }
    true
}

fn shard_loop<A: HostApp>(
    shard: &mut Shard<A>,
    rx: &mpsc::Receiver<PacketBatch>,
    tx: &mpsc::Sender<PacketBatch>,
    stop: &AtomicBool,
    idle: IdlePolicy,
    cpu: Arc<CpuLedger>,
    burst_cap: usize,
) {
    let burst_cap = burst_cap.max(1);
    let mut gov = IdleGovernor::new(idle, cpu);
    let mut outs: Vec<PacketBatch> = Vec::new();
    let mut burst = Burst::with_capacity(burst_cap);
    let mut disconnected = false;
    loop {
        let mut progressed = false;
        // Drain stage: gather one bounded input burst WITHOUT servicing
        // anything yet (batching without extra latency) — bounded so a
        // producer that outpaces this shard can't starve the response
        // path, and `stop` is re-checked inside the drain (regression,
        // PR 5: stop used to be observed only on the recv-timeout arm,
        // so sustained input pinned the thread until channel
        // disconnect).
        for _ in 0..burst_cap {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match rx.try_recv() {
                Ok((tuple, segs)) => {
                    progressed = true;
                    burst.push(tuple, segs);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // Service stages: the whole burst runs decode → service → host
        // exchange → completion drain as a unit; per-burst, not
        // per-batch, bookkeeping.
        shard.step_burst(&mut burst, &mut outs);
        // Late engine completions (async SSD queues, pending aborts) —
        // also covers the empty-burst pass.
        let before = outs.len();
        shard.poll(&mut outs);
        progressed |= outs.len() > before;
        // Flush BEFORE parking or exiting — gathered responses must
        // not sit behind a sleeping shard or be dropped on shutdown.
        // Burst boundaries remain the ONLY park points: a drained
        // batch is always serviced and flushed in the same pass.
        if !flush_outs(&mut outs, tx) {
            return;
        }
        gov.iteration(progressed);
        if disconnected || stop.load(Ordering::Relaxed) {
            drain_on_exit(shard, tx, &mut outs);
            return;
        }
        if !progressed {
            if shard.in_flight() > 0 {
                // Completions land on this shard's own poll loop — no
                // doorbell can ring them home, so nap (bounded, short)
                // instead of a full park.
                gov.idle_nap();
            } else {
                // Nothing anywhere: park on the input channel. The
                // channel is its own doorbell — a send during the park
                // wakes the pump, so no wakeup can be lost — and the
                // park is bounded by the policy's backoff.
                match gov.idle_recv(rx) {
                    IdleRecv::Got((tuple, segs)) => {
                        // Outputs flush at the top of the next pass,
                        // which follows immediately (no park between
                        // a wake and its flush). Book the wake-driven
                        // batch as a productive pass and reset the
                        // ladder for the burst that usually follows.
                        burst.push(tuple, segs);
                        shard.step_burst(&mut burst, &mut outs);
                        gov.woke_with_work();
                    }
                    IdleRecv::Empty => {}
                    IdleRecv::Disconnected => {
                        drain_on_exit(shard, tx, &mut outs);
                        return;
                    }
                }
            }
        }
    }
}

/// Final drain on shard exit: in-flight engine completions must still
/// reach their clients (regression, PR 5: in-flight responses at stop
/// time are flushed, not dropped). Bounded — a completion the fault
/// plane swallowed is aborted as ERR by the engine's pending timeout,
/// so the wait cannot exceed it by more than scheduling slack.
fn drain_on_exit<A: HostApp>(
    shard: &mut Shard<A>,
    tx: &mpsc::Sender<PacketBatch>,
    outs: &mut Vec<PacketBatch>,
) {
    let bound = shard.director.engine().pending_timeout() + Duration::from_secs(1);
    let deadline = Instant::now() + bound;
    loop {
        shard.poll(outs);
        if !flush_outs(outs, tx) {
            return;
        }
        if shard.in_flight() == 0 || Instant::now() >= deadline {
            return;
        }
        // LINT: sleep-ok(bounded shutdown drain off the hot path; the loop
        // is deadline-capped just above)
        std::thread::sleep(Duration::from_micros(100));
    }
}

/// The N-shard DDS deployment: one thread per shard running the whole
/// DPU data path, fed through per-shard input queues and drained
/// through per-shard output queues.
pub struct ShardedServer {
    /// The shared storage path (SSD + DpuFs + cache + file service).
    pub storage: StorageServer,
    /// Shard count, fixed at build time (stable across shutdown so
    /// steering queries never divide by zero).
    shards: usize,
    inputs: Vec<mpsc::Sender<PacketBatch>>,
    outputs: Vec<Mutex<mpsc::Receiver<PacketBatch>>>,
    stats: Vec<Arc<ShardStats>>,
    /// Per-shard engine buffer pools (handles cloned out before the
    /// engines moved into their shard threads — occupancy and copy
    /// ledger stay observable; the chaos suite's leak check).
    engine_pools: Vec<crate::buf::BufPool>,
    /// Per-shard engine-failure injection flags (fault plane).
    fail_flags: Vec<Arc<AtomicBool>>,
    /// Per-shard pump CPU ledgers (written by the shard threads' idle
    /// governors; readable any time, including after shutdown).
    cpu: Vec<Arc<CpuLedger>>,
    /// Per-shard director latency recorders (written lock-free by the
    /// shard threads; merged at snapshot).
    lat: Vec<Arc<LatencyHistogram>>,
    /// Per-shard tenant counter tables (published by the shard pumps;
    /// merged at snapshot).
    tenants: Vec<Arc<Mutex<Vec<TenantCounters>>>>,
    joins: Vec<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl ShardedServer {
    /// Build the storage path and spawn `cfg.shards` shard threads.
    /// `mk_app(shard, &storage)` builds each shard's host-application
    /// instance — typically with its own poll group, giving the file
    /// service one group per shard to drain round-robin.
    pub fn build<A, F>(
        cfg: ShardedServerConfig,
        logic: Arc<dyn OffloadLogic>,
        signature: AppSignature,
        mk_app: F,
    ) -> anyhow::Result<Self>
    where
        A: HostApp + Send + 'static,
        F: FnMut(usize, &StorageServer) -> anyhow::Result<A>,
    {
        let storage = StorageServer::build(cfg.server.clone(), Some(logic.clone()))?;
        Self::over(storage, cfg, logic, signature, mk_app)
    }

    /// Spawn the shards over an existing storage path (lets callers
    /// create and pre-populate files before the shards start).
    /// `cfg.server` is NOT consumed here — it only describes how
    /// [`Self::build`] would construct the storage path; the `storage`
    /// argument is used as-is.
    pub fn over<A, F>(
        storage: StorageServer,
        cfg: ShardedServerConfig,
        logic: Arc<dyn OffloadLogic>,
        signature: AppSignature,
        mut mk_app: F,
    ) -> anyhow::Result<Self>
    where
        A: HostApp + Send + 'static,
        F: FnMut(usize, &StorageServer) -> anyhow::Result<A>,
    {
        anyhow::ensure!(cfg.shards >= 1, "need at least one shard");
        let n = cfg.shards;
        let engine_cfg = cfg.engine_total.per_shard(n);
        let queues = storage.shard_aios(n, cfg.queue_workers);
        let stop = Arc::new(AtomicBool::new(false));
        let mut inputs = Vec::with_capacity(n);
        let mut outputs = Vec::with_capacity(n);
        let mut stats = Vec::with_capacity(n);
        let mut engine_pools = Vec::with_capacity(n);
        let mut fail_flags = Vec::with_capacity(n);
        let mut cpu = Vec::with_capacity(n);
        let mut lat = Vec::with_capacity(n);
        let mut tenants = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for (i, mut aio) in queues.into_iter().enumerate() {
            if let Some(plane) = &cfg.faults {
                aio.attach_faults(plane.ssd_injector(FaultSite::SsdQueue(i)));
            }
            let mut engine = OffloadEngine::new(
                logic.clone(),
                storage.cache.clone(),
                storage.dpufs.clone(),
                aio,
                engine_cfg.clone(),
            );
            if let Some(tier) = &storage.tier {
                // One tier per server, shared by every shard's engine:
                // the tier models one pool of DPU memory, and its
                // internal locking is per-slot/per-bucket, so shards
                // don't serialize on it.
                engine.attach_tier(tier.clone());
            }
            engine_pools.push(engine.pool().clone());
            let mut director =
                DirectorShard::new(i, signature, logic.clone(), storage.cache.clone(), engine);
            director.configure_tenants(cfg.tenants.clone());
            let shard_lat = LatencyHistogram::new();
            director.attach_latency(shard_lat.clone());
            storage.register_latency_recorder(shard_lat.clone());
            let shard_tenants = Arc::new(Mutex::new(director.tenant_counters()));
            storage.register_tenant_source(shard_tenants.clone());
            let app = mk_app(i, &storage)?;
            let shard_stats = Arc::new(ShardStats::default());
            let fail_flag = Arc::new(AtomicBool::new(false));
            let mut shard = Shard {
                director,
                app,
                host_conns: HashMap::new(),
                stats: shard_stats.clone(),
                fail_flag: fail_flag.clone(),
                douts: Vec::new(),
                pumped: Vec::new(),
                tenant_pub: shard_tenants.clone(),
            };
            let (in_tx, in_rx) = mpsc::channel();
            let (out_tx, out_rx) = mpsc::channel();
            let stop2 = stop.clone();
            let ledger = CpuLedger::new();
            let ledger2 = ledger.clone();
            let idle = cfg.idle;
            let burst = cfg.burst;
            let join = std::thread::Builder::new()
                .name(format!("dds-shard-{i}"))
                .spawn(move || {
                    shard_loop(&mut shard, &in_rx, &out_tx, &stop2, idle, ledger2, burst)
                })
                .map_err(|e| anyhow::anyhow!("spawn shard {i}: {e}"))?;
            inputs.push(in_tx);
            outputs.push(Mutex::new(out_rx));
            stats.push(shard_stats);
            fail_flags.push(fail_flag);
            cpu.push(ledger);
            lat.push(shard_lat);
            tenants.push(shard_tenants);
            joins.push(join);
        }
        Ok(ShardedServer {
            storage,
            shards: n,
            inputs,
            outputs,
            stats,
            engine_pools,
            fail_flags,
            cpu,
            lat,
            tenants,
            joins,
            stop,
        })
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards
    }

    /// Per-shard engine buffer pools (index = shard id). Alive even
    /// after shutdown, so leak checks can assert occupancy returns to
    /// zero once the shard threads have been joined.
    pub fn engine_pools(&self) -> &[crate::buf::BufPool] {
        &self.engine_pools
    }

    /// RSS steering: the shard that owns `tuple`.
    pub fn shard_of(&self, tuple: &FiveTuple) -> usize {
        rss_core(tuple, self.shards)
    }

    /// Route one batch of client segments to its flow's shard.
    /// Errors (does not panic) once the server has been shut down.
    pub fn send(&self, tuple: &FiveTuple, segs: Vec<Segment>) -> anyhow::Result<()> {
        let shard = self.shard_of(tuple);
        anyhow::ensure!(!self.inputs.is_empty(), "server is shut down");
        self.inputs[shard]
            .send((*tuple, segs))
            .map_err(|_| anyhow::anyhow!("shard {shard} is gone"))
    }

    /// Wait up to `timeout` for one batch of segments headed back to a
    /// client of `shard`. `None` for an out-of-range shard (no panic,
    /// matching [`Self::send`]).
    pub fn recv_timeout(&self, shard: usize, timeout: Duration) -> Option<PacketBatch> {
        self.outputs.get(shard)?.lock().unwrap().recv_timeout(timeout).ok()
    }

    /// Non-blocking variant of [`Self::recv_timeout`].
    pub fn try_recv(&self, shard: usize) -> Option<PacketBatch> {
        self.outputs.get(shard)?.lock().unwrap().try_recv().ok()
    }

    /// Inject (`true`) or clear (`false`) failure of one shard's
    /// offload engine. The shard thread applies the change at its next
    /// iteration: in-flight engine contexts abort as ERR and subsequent
    /// requests route through the host slow path (the paper's
    /// fallback). Returns false for an out-of-range shard.
    pub fn set_engine_failed(&self, shard: usize, failed: bool) -> bool {
        match self.fail_flags.get(shard) {
            Some(flag) => {
                flag.store(failed, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Per-shard counter snapshots.
    pub fn shard_stats(&self) -> Vec<DirectorShardStats> {
        self.stats.iter().enumerate().map(|(i, s)| s.snapshot(i)).collect()
    }

    /// Per-shard pump CPU snapshots (index = shard id): iterations,
    /// parks, wakes, busy fraction — the shard half of the functional
    /// Fig 14 CPU axis (the file service's half is
    /// `self.storage.cpu_stats()`).
    pub fn cpu_stats(&self) -> Vec<CpuStats> {
        self.cpu.iter().map(|l| l.snapshot()).collect()
    }

    /// Every pump of the deployment in the canonical order: index 0 is
    /// the file service, then one entry per shard. The ONE "all pumps"
    /// view — the chaos harness, benches and tests all meter this, so
    /// a future pump only has to be added here.
    pub fn all_cpu_stats(&self) -> Vec<CpuStats> {
        let mut v = vec![self.storage.cpu_stats()];
        v.extend(self.cpu_stats());
        v
    }

    /// Merged per-request service-latency snapshot across every shard
    /// director (recorded lock-free per pump at request admission →
    /// response framing; merged here, at read time). Subtract two of
    /// these with [`LatencySnapshot::since`] to meter a load window.
    pub fn latency_snapshot(&self) -> LatencySnapshot {
        let mut acc = LatencySnapshot::default();
        for l in &self.lat {
            acc.merge(&l.snapshot());
        }
        acc
    }

    /// Quantile summary of [`Self::latency_snapshot`].
    pub fn latency_stats(&self) -> LatencyStats {
        self.latency_snapshot().stats()
    }

    /// Per-tenant counters merged across every shard (indexed by
    /// tenant id, ascending). The fanout plane's QoS ledger: admitted,
    /// completed, rejected (pending bound), throttled (rate limit),
    /// pending/flows gauges, and flow-cap rejections.
    pub fn tenant_stats(&self) -> Vec<TenantCounters> {
        let tables: Vec<Vec<TenantCounters>> =
            self.tenants.iter().map(|t| t.lock().unwrap().clone()).collect();
        merge_tenant_tables(&tables)
    }

    /// Aggregate counters across every shard.
    pub fn stats(&self) -> DirectorShardStats {
        let mut acc = DirectorShardStats::default();
        for s in self.shard_stats() {
            acc = acc.merge(&s);
        }
        acc
    }

    /// Stop and join every shard thread (idempotent; also runs on drop).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.inputs.clear(); // disconnects every shard's input queue
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for ShardedServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Find a client tuple that RSS steers to `shard` out of `shards`, by
/// scanning client ports from `base_port` (panics if no port maps —
/// impossible in practice for any healthy hash).
pub fn tuple_for_shard(
    shard: usize,
    shards: usize,
    client_ip: u32,
    base_port: u16,
    server_ip: u32,
    server_port: u16,
) -> FiveTuple {
    assert!(shard < shards);
    let mut port = base_port;
    loop {
        let t = FiveTuple::new(client_ip, port, server_ip, server_port);
        if rss_core(&t, shards) == shard {
            return t;
        }
        port = port.wrapping_add(1);
        assert!(port != base_port, "no client port steers to shard {shard}/{shards}");
    }
}

/// Client-side pump for one shard: owns the [`ClientConn`]s of every
/// connection steered to that shard and exchanges segments with the
/// server on their behalf. A batch received for a tuple this driver
/// does not own is an error — which is exactly the "no cross-shard
/// leakage" property the integration tests assert.
pub struct ShardDriver {
    shard: usize,
    conns: HashMap<FiveTuple, ClientConn>,
}

impl ShardDriver {
    pub fn new(shard: usize) -> Self {
        ShardDriver { shard, conns: HashMap::new() }
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Register a connection; the tuple must steer to this driver's
    /// shard.
    pub fn connect(&mut self, server: &ShardedServer, tuple: FiveTuple) -> anyhow::Result<()> {
        anyhow::ensure!(
            server.shard_of(&tuple) == self.shard,
            "tuple steers to shard {}, driver owns shard {}",
            server.shard_of(&tuple),
            self.shard
        );
        self.conns.insert(tuple, ClientConn::new(tuple));
        Ok(())
    }

    /// Frame `msg` on `tuple`'s connection and put it on the wire.
    pub fn send(
        &mut self,
        server: &ShardedServer,
        tuple: &FiveTuple,
        msg: &NetMsg,
    ) -> anyhow::Result<()> {
        let conn = self
            .conns
            .get_mut(tuple)
            .ok_or_else(|| anyhow::anyhow!("unknown connection {tuple:?}"))?;
        let segs = conn.send_msg(msg);
        server.send(tuple, segs)
    }

    /// Wait up to `timeout` for server segments, absorb them (sending
    /// ACKs back), and return every decoded response with its tuple.
    pub fn pump(
        &mut self,
        server: &ShardedServer,
        timeout: Duration,
    ) -> anyhow::Result<Vec<(FiveTuple, NetResp)>> {
        let mut got = Vec::new();
        let Some((t, segs)) = server.recv_timeout(self.shard, timeout) else {
            return Ok(got);
        };
        self.absorb(server, t, segs, &mut got)?;
        while let Some((t, segs)) = server.try_recv(self.shard) {
            self.absorb(server, t, segs, &mut got)?;
        }
        Ok(got)
    }

    fn absorb(
        &mut self,
        server: &ShardedServer,
        tuple: FiveTuple,
        segs: Vec<Segment>,
        got: &mut Vec<(FiveTuple, NetResp)>,
    ) -> anyhow::Result<()> {
        let conn = self.conns.get_mut(&tuple).ok_or_else(|| {
            anyhow::anyhow!(
                "shard {} emitted segments for a connection it does not own: {tuple:?}",
                self.shard
            )
        })?;
        let mut acks = Vec::new();
        let resps = conn.on_segments(&segs, &mut acks);
        if !acks.is_empty() {
            server.send(&tuple, acks)?;
        }
        got.extend(resps.into_iter().map(|r| (tuple, r)));
        Ok(())
    }
}

/// Drive one message fully through a sharded server and wait for all of
/// its responses (test/example helper; the sharded analog of
/// [`super::run_request`]).
pub fn run_sharded_request(
    server: &ShardedServer,
    driver: &mut ShardDriver,
    tuple: &FiveTuple,
    msg: &NetMsg,
    timeout: Duration,
) -> anyhow::Result<Vec<NetResp>> {
    let expect = msg.requests.len();
    let mut seen = vec![false; expect];
    let mut out: Vec<NetResp> = Vec::new();
    driver.send(server, tuple, msg)?;
    let deadline = Instant::now() + timeout;
    while out.len() < expect {
        let now = Instant::now();
        anyhow::ensure!(now < deadline, "request timed out");
        let wait = (deadline - now).min(Duration::from_millis(50));
        for (t, r) in driver.pump(server, wait)? {
            // Late/duplicate responses (TCP retransmits, earlier
            // messages) must not be attributed to this request.
            if t != *tuple || r.msg_id != msg.msg_id {
                continue;
            }
            let idx = r.idx as usize;
            if idx < expect && !seen[idx] {
                seen[idx] = true;
                out.push(r);
            }
        }
    }
    out.sort_by_key(|r| r.idx);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CuckooCache;
    use crate::dpufs::{DpuFs, FsConfig};
    use crate::offload::NoOffload;
    use crate::ssd::{AsyncSsd, Ssd};
    use std::sync::RwLock;

    /// Host app that answers nothing (the loop mechanics, not the data
    /// path, are under test).
    struct NullApp;
    impl HostApp for NullApp {
        fn handle(&mut self, _msg: &NetMsg) -> Vec<NetResp> {
            Vec::new()
        }
    }

    fn mk_shard() -> Shard<NullApp> {
        let ssd = Arc::new(Ssd::new(4 << 20, 512));
        let fs = DpuFs::format(ssd.clone(), FsConfig::default()).unwrap();
        let cache = Arc::new(CuckooCache::new(64));
        let engine = OffloadEngine::new(
            Arc::new(NoOffload),
            cache.clone(),
            Arc::new(RwLock::new(fs)),
            AsyncSsd::new_inline(ssd),
            OffloadEngineConfig::default(),
        );
        let director =
            DirectorShard::new(0, AppSignature::server_port(5000), Arc::new(NoOffload), cache, engine);
        Shard {
            director,
            app: NullApp,
            host_conns: HashMap::new(),
            stats: Arc::new(ShardStats::default()),
            fail_flag: Arc::new(AtomicBool::new(false)),
            douts: Vec::new(),
            pumped: Vec::new(),
            tenant_pub: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Regression (PR 5): `stop` used to be observed only on the
    /// recv-timeout arm, so a producer that kept the input channel
    /// non-empty pinned the shard thread until channel disconnect.
    /// With the sender kept alive and saturating, stop must still exit
    /// the loop in bounded time.
    #[test]
    fn shard_loop_observes_stop_under_sustained_input() {
        let mut shard = mk_shard();
        let (in_tx, in_rx) = mpsc::channel::<PacketBatch>();
        let (out_tx, _out_rx) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let pump = std::thread::spawn(move || {
            shard_loop(
                &mut shard,
                &in_rx,
                &out_tx,
                &stop2,
                IdlePolicy::default(),
                CpuLedger::new(),
                64,
            )
        });
        // Saturating producer on a non-matching tuple (forward path:
        // counted, no per-flow state) — keeps the channel non-empty
        // and the sender ALIVE for the whole test.
        let feeding = Arc::new(AtomicBool::new(true));
        let f2 = feeding.clone();
        let producer = std::thread::spawn(move || {
            let tuple = FiveTuple::new(1, 2, 3, 9999);
            'outer: while f2.load(Ordering::Relaxed) {
                // Paced bursts: fast enough that the channel is
                // essentially never empty for the recv-timeout arm's
                // full 1 ms (the only place the old code checked
                // stop), slow enough to bound the backlog.
                for _ in 0..128 {
                    let seg = Segment { seq: 0, payload: crate::buf::BufView::empty(), ack: 0 };
                    if in_tx.send((tuple, vec![seg])).is_err() {
                        break 'outer;
                    }
                }
                std::thread::sleep(Duration::from_micros(100));
            }
        });
        std::thread::sleep(Duration::from_millis(50));
        stop.store(true, Ordering::Relaxed);
        let deadline = Instant::now() + Duration::from_secs(5);
        while !pump.is_finished() {
            assert!(
                Instant::now() < deadline,
                "shard thread ignored stop under sustained input"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        pump.join().unwrap();
        feeding.store(false, Ordering::Relaxed);
        producer.join().unwrap();
    }

    /// An idle shard under the default Adaptive policy parks (its CPU
    /// ledger proves it) and still exits promptly on disconnect.
    #[test]
    fn idle_shard_parks_and_exits_on_disconnect() {
        let mut shard = mk_shard();
        let (in_tx, in_rx) = mpsc::channel::<PacketBatch>();
        let (out_tx, _out_rx) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let ledger = CpuLedger::new();
        let ledger2 = ledger.clone();
        let pump = std::thread::spawn(move || {
            shard_loop(&mut shard, &in_rx, &out_tx, &stop2, IdlePolicy::default(), ledger2, 64)
        });
        std::thread::sleep(Duration::from_millis(100));
        let s = ledger.snapshot();
        assert!(s.parks > 0, "idle shard never parked: {s:?}");
        let t0 = Instant::now();
        drop(in_tx); // disconnect = shutdown signal
        pump.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(2), "disconnect did not wake the park");
    }
}
