//! Storage-server assembly and end-to-end wiring.
//!
//! [`StorageServer`] builds the DPU stack (SSD → file system → file
//! service → cache table) and hands the host a [`DdsClient`] front end —
//! the §4 unified storage path.
//!
//! [`DisaggregatedServer`] adds the §5/§6 network path: a traffic
//! director with PEP-split connections, an offload engine, and a host
//! application, all pumpable from an in-process [`ClientConn`]. This is
//! the full DDS deployment used by the examples and integration tests:
//! client → (TCP) → DPU director → {offload engine | host app} → client.
//! It is the N = 1, single-flow, synchronous special case of the
//! sharded data plane.
//!
//! [`ShardedServer`] (in [`sharded`]) is the N-core generalization
//! (§7): RSS steers every flow to one of N share-nothing shards, each
//! running the whole DPU data path — per-flow split-TCP PEPs, its own
//! offload engine over its own SSD queue, and its own host-app
//! instance draining a dedicated file-service poll group — on its own
//! OS thread.

pub mod sharded;

pub use sharded::{
    run_sharded_request, tuple_for_shard, ShardDriver, ShardedServer, ShardedServerConfig,
};

use std::sync::{mpsc, Arc, RwLock};

use crate::apps::HostApp;
use crate::cache::{CuckooCache, ReadCacheTier};
use crate::director::{AppSignature, TrafficDirector};
use crate::dpufs::{DpuFs, FsConfig};
use crate::filelib::DdsClient;
use crate::fileservice::{ControlMsg, FileService, FileServiceConfig, FileServiceHandle};
use crate::net::tcp::{Segment, TcpEndpoint};
use crate::net::FiveTuple;
use crate::offload::{NoOffload, OffloadEngine, OffloadEngineConfig, OffloadLogic};
use crate::proto::{framing, NetMsg, NetResp};
use crate::ssd::{AsyncSsd, Ssd};

/// Storage-server build options.
#[derive(Clone)]
pub struct StorageServerConfig {
    pub ssd_bytes: u64,
    pub segment_size: u64,
    pub cache_items: usize,
    /// DPU read-cache tier byte budget. `0` (the default) disables the
    /// tier — READs always go to the SSD, exactly the pre-tier
    /// behavior. When set, one tier is built per server and shared by
    /// the file service and every offload engine (DPU memory is one
    /// resource), with write-through invalidation from both WRITE
    /// paths.
    pub cache_bytes: u64,
    pub service: FileServiceConfig,
}

impl Default for StorageServerConfig {
    fn default() -> Self {
        StorageServerConfig {
            ssd_bytes: 256 << 20,
            segment_size: 1 << 20,
            cache_items: 1 << 16,
            cache_bytes: 0,
            service: FileServiceConfig::default(),
        }
    }
}

/// The unified storage path: DPU-owned SSD + file system + file service,
/// host-side front end.
pub struct StorageServer {
    pub ssd: Arc<Ssd>,
    pub dpufs: Arc<RwLock<DpuFs>>,
    pub cache: Arc<CuckooCache>,
    /// The DPU read-cache tier (`cfg.cache_bytes > 0`), shared by the
    /// file service and every engine built over this server.
    pub tier: Option<Arc<ReadCacheTier>>,
    pub handle: FileServiceHandle,
    /// Handle on the file service's batch/assembly pool (occupancy +
    /// the plane-wide copy ledger, observable from outside the service
    /// thread).
    pub buf_pool: crate::buf::BufPool,
    /// Handle on the file service's read-completion pool (shares the
    /// ledger with `buf_pool`; separate occupancy).
    pub read_buf_pool: crate::buf::BufPool,
    ctrl: mpsc::Sender<ControlMsg>,
    /// The service pump's wake doorbell (every front end rings it on
    /// control sends and request pushes; see the CPU plane in
    /// DESIGN.md).
    service_wake: std::sync::Arc<crate::idle::Doorbell>,
    /// The service pump's CPU ledger (direct handle — no control
    /// round trip, safe to read while the service is parked).
    cpu: std::sync::Arc<crate::metrics::CpuLedger>,
    /// The file service's own latency recorder (staging allocation →
    /// response delivered; direct handle, like `cpu`).
    lat: std::sync::Arc<crate::metrics::LatencyHistogram>,
    /// Peer recorders folded into `ControlMsg::LatencyStats` replies —
    /// outer assemblies (director shards) register theirs here.
    lat_peers:
        std::sync::Arc<std::sync::Mutex<Vec<std::sync::Arc<crate::metrics::LatencyHistogram>>>>,
    /// Per-shard tenant counter tables folded into
    /// `ControlMsg::TenantStats` replies (the fanout plane's QoS
    /// ledger), registered the same way as `lat_peers`.
    tenant_peers: std::sync::Arc<
        std::sync::Mutex<
            Vec<std::sync::Arc<std::sync::Mutex<Vec<crate::metrics::TenantCounters>>>>,
        >,
    >,
    /// Build options (kept for introspection / future rebuilds).
    pub cfg: StorageServerConfig,
}

impl StorageServer {
    /// Format the device and spawn the file service.
    pub fn build(
        cfg: StorageServerConfig,
        logic: Option<Arc<dyn OffloadLogic>>,
    ) -> anyhow::Result<Self> {
        let ssd = Arc::new(Ssd::new(cfg.ssd_bytes, 512));
        let fs = DpuFs::format(ssd.clone(), FsConfig { segment_size: cfg.segment_size })
            .map_err(|e| anyhow::anyhow!("format: {e}"))?;
        Self::over_device(ssd, fs, cfg, logic, None)
    }

    /// The restart path: mount an existing device image — running the
    /// metadata journal's crash recovery — instead of formatting, and
    /// report what recovery found and repaired. `cfg.ssd_bytes` is
    /// ignored (the device already exists); `cfg.segment_size` must
    /// match the on-disk layout.
    pub fn remount(
        ssd: Arc<Ssd>,
        cfg: StorageServerConfig,
        logic: Option<Arc<dyn OffloadLogic>>,
    ) -> anyhow::Result<(Self, crate::dpufs::RecoveryReport)> {
        let (fs, report) =
            DpuFs::mount_with_report(ssd.clone(), FsConfig { segment_size: cfg.segment_size })
                .map_err(|e| anyhow::anyhow!("mount: {e}"))?;
        Ok((Self::over_device(ssd, fs, cfg, logic, Some(report.clone()))?, report))
    }

    /// Spawn the file service over an already-built device + file
    /// system (shared tail of [`Self::build`] and [`Self::remount`]).
    /// A remount passes its [`crate::dpufs::RecoveryReport`] so the
    /// service can answer `ControlMsg::RecoveryReport` round trips.
    fn over_device(
        ssd: Arc<Ssd>,
        fs: DpuFs,
        cfg: StorageServerConfig,
        logic: Option<Arc<dyn OffloadLogic>>,
        recovery: Option<crate::dpufs::RecoveryReport>,
    ) -> anyhow::Result<Self> {
        let dpufs = Arc::new(RwLock::new(fs));
        let cache = Arc::new(CuckooCache::new(cfg.cache_items));
        let aio = AsyncSsd::new(ssd.clone(), cfg.service.ssd_workers);
        let (mut service, ctrl) =
            FileService::new(dpufs.clone(), aio, cfg.service.clone(), logic, cache.clone());
        if let Some(report) = recovery {
            service.set_recovery_report(report);
        }
        let tier = if cfg.cache_bytes > 0 {
            let tier = Arc::new(ReadCacheTier::new(cfg.cache_bytes));
            service.attach_tier(tier.clone());
            // Durable-path invalidation: the remap COMMIT (mapping
            // flip) is the ack point of a durable write — the hook
            // fires per redirected segment, after the flip, under the
            // fs write lock, so no probe can land between new bytes
            // becoming readable and the old cached view dying.
            let hook_tier = tier.clone();
            dpufs.write().unwrap().set_remap_commit_hook(Arc::new(move |file, off, len| {
                hook_tier.invalidate(file.0 as u64, off, len);
            }));
            Some(tier)
        } else {
            None
        };
        let buf_pool = service.buf_pool().clone();
        let read_buf_pool = service.read_buf_pool().clone();
        let service_wake = service.waker();
        let cpu = service.cpu_ledger();
        let lat = service.latency_recorder();
        let lat_peers = service.latency_peers();
        let tenant_peers = service.tenant_peers();
        let handle = service.spawn(ctrl.clone());
        Ok(StorageServer {
            ssd,
            dpufs,
            cache,
            tier,
            handle,
            buf_pool,
            read_buf_pool,
            ctrl,
            service_wake,
            cpu,
            lat,
            lat_peers,
            tenant_peers,
            cfg,
        })
    }

    /// A host-side front-end client (§4.2). Create one per application.
    pub fn front_end(&self) -> DdsClient {
        DdsClient::new(self.ctrl.clone(), self.service_wake.clone())
    }

    /// CPU ledger snapshot of the file-service pump (direct handle;
    /// does not wake a parked service the way the
    /// [`DdsClient::cpu_stats`] control round trip would).
    pub fn cpu_stats(&self) -> crate::metrics::CpuStats {
        self.cpu.snapshot()
    }

    /// The service pump's wake doorbell (for callers that talk to the
    /// service through the raw control sender and need to ring it).
    pub fn service_waker(&self) -> std::sync::Arc<crate::idle::Doorbell> {
        self.service_wake.clone()
    }

    /// Register a peer latency recorder (a director shard's, say) so
    /// the control plane's `LatencyStats` reply — and
    /// [`Self::latency_stats`] — report the whole deployment's
    /// trajectory, not just the file service's own.
    pub fn register_latency_recorder(
        &self,
        recorder: std::sync::Arc<crate::metrics::LatencyHistogram>,
    ) {
        self.lat_peers.lock().unwrap().push(recorder);
    }

    /// Merged latency summary: the file service's staging-to-delivery
    /// recorder plus every registered peer. Direct handle — does not
    /// wake a parked service the way the [`DdsClient::latency_stats`]
    /// control round trip would.
    pub fn latency_stats(&self) -> crate::metrics::LatencyStats {
        let mut merged = self.lat.snapshot();
        for peer in self.lat_peers.lock().unwrap().iter() {
            merged.merge(&peer.snapshot());
        }
        merged.stats()
    }

    /// Register a per-shard tenant counter table so the control plane's
    /// `TenantStats` reply — and [`Self::tenant_stats`] — covers the
    /// whole deployment.
    pub fn register_tenant_source(
        &self,
        source: std::sync::Arc<std::sync::Mutex<Vec<crate::metrics::TenantCounters>>>,
    ) {
        self.tenant_peers.lock().unwrap().push(source);
    }

    /// Per-tenant counters merged across every registered source
    /// (direct handle; does not wake a parked service).
    pub fn tenant_stats(&self) -> Vec<crate::metrics::TenantCounters> {
        let tables: Vec<Vec<crate::metrics::TenantCounters>> = self
            .tenant_peers
            .lock()
            .unwrap()
            .iter()
            .map(|t| t.lock().unwrap().clone())
            .collect();
        crate::metrics::merge_tenant_tables(&tables)
    }

    /// An SPDK-like async handle for the offload engine (the engine
    /// shares the device with the file service, §6.2). Inline polled
    /// mode: the engine colocates with the director on one DPU core
    /// (§7), and the perf pass showed worker handoff dominating the
    /// single-core profile (EXPERIMENTS.md §Perf L3-3).
    pub fn engine_aio(&self) -> AsyncSsd {
        AsyncSsd::new_inline(self.ssd.clone())
    }

    /// Per-shard SPDK-like queues over the shared device (§7): each
    /// shard's engine submits and polls on its own queue, so shards
    /// never contend on a shared submission/completion queue.
    /// `workers_per_queue == 0` keeps every queue in inline polled mode.
    pub fn shard_aios(&self, shards: usize, workers_per_queue: usize) -> Vec<AsyncSsd> {
        AsyncSsd::shard_queues(&self.ssd, shards, workers_per_queue)
    }

    /// Create `dir_name/file_name` and fill it with the deterministic
    /// benchmark pattern (`i % 253` — the one
    /// [`crate::workload::RandomIoGen::expected_fill`] reproduces)
    /// using ring-friendly chunked writes with `RingFull`
    /// backpressure. The canonical setup step of the benches, tests,
    /// examples, and the `serve` CLI.
    pub fn create_filled_file(
        &self,
        dir_name: &str,
        file_name: &str,
        bytes: u64,
    ) -> anyhow::Result<crate::filelib::DdsFile> {
        use std::time::Duration;
        let fe = self.front_end();
        let dir = fe.create_directory(dir_name).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut file = fe.create_file(dir, file_name).map_err(|e| anyhow::anyhow!("{e}"))?;
        let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
        fe.poll_add(&mut file, &group);
        let chunk = 64usize << 10;
        let mut pending = std::collections::HashSet::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(60);
        for off in (0..bytes).step_by(chunk) {
            let len = chunk.min((bytes - off) as usize);
            let data: Vec<u8> = (off..off + len as u64).map(|i| (i % 253) as u8).collect();
            // Non-blocking issue with RingFull backpressure: drain
            // completions until the ring admits the next write.
            loop {
                match fe.write_file(&file, off, &data) {
                    Ok(id) => {
                        pending.insert(id);
                        break;
                    }
                    Err(crate::filelib::LibError::RingFull) => {
                        for ev in group.poll_wait(Duration::from_millis(10)) {
                            anyhow::ensure!(ev.ok, "fill write failed");
                            pending.remove(&ev.req_id);
                        }
                        anyhow::ensure!(
                            std::time::Instant::now() < deadline,
                            "fill stalled on ring backpressure"
                        );
                    }
                    Err(e) => anyhow::bail!("fill write: {e}"),
                }
            }
        }
        while !pending.is_empty() {
            for ev in group.poll_wait(Duration::from_millis(50)) {
                anyhow::ensure!(ev.ok, "fill write failed");
                pending.remove(&ev.req_id);
            }
            anyhow::ensure!(std::time::Instant::now() < deadline, "fill completions lost");
        }
        Ok(file)
    }
}

/// Deliver DPU→host segments into a host application through the given
/// host-side endpoint: absorb the segments, hand complete frames to the
/// app, and return the segments (ACKs + framed responses) the host puts
/// back on the wire toward the DPU. Shared by the singleton
/// [`DisaggregatedServer`] pump and the per-shard pump in [`sharded`].
pub(crate) fn host_exchange<A: HostApp>(
    app: &mut A,
    ep: &mut TcpEndpoint,
    rx: &mut framing::StreamBuf,
    segs: &[Segment],
) -> Vec<Segment> {
    let mut back_to_dpu = Vec::new();
    for s in segs {
        back_to_dpu.extend(ep.on_segment(s));
    }
    let delivered = ep.deliver_rope();
    rx.extend_rope(&delivered, ep.ledger());
    // Host app handles complete messages.
    let mut responses = Vec::new();
    while let Some(frame) = rx.read_frame() {
        if let Some(msg) = NetMsg::decode(&frame) {
            responses.extend(app.handle(&msg));
        }
    }
    if !responses.is_empty() {
        // Frame into a view rope: response payloads (e.g. poll-group
        // read data) ride by reference onto connection 2.
        let mut rope = crate::buf::ByteRope::new();
        for r in responses {
            r.frame_into_rope(&mut rope);
        }
        back_to_dpu.extend(ep.send_rope(rope));
    }
    back_to_dpu
}

/// One client connection speaking the app protocol over the simulated
/// transport.
pub struct ClientConn {
    pub ep: TcpEndpoint,
    pub tuple: FiveTuple,
    rx: framing::StreamBuf,
}

impl ClientConn {
    pub fn new(tuple: FiveTuple) -> Self {
        ClientConn { ep: TcpEndpoint::new(), tuple, rx: framing::StreamBuf::new() }
    }

    /// Frame and segment a message for the wire.
    pub fn send_msg(&mut self, msg: &NetMsg) -> Vec<Segment> {
        let mut stream = Vec::new();
        framing::write_frame(&mut stream, &msg.encode());
        self.ep.send(&stream)
    }

    /// Absorb server segments; returns decoded responses (and emits the
    /// ACKs to send back via `out`).
    pub fn on_segments(&mut self, segs: &[Segment], out: &mut Vec<Segment>) -> Vec<NetResp> {
        for s in segs {
            out.extend(self.ep.on_segment(s));
        }
        let delivered = self.ep.deliver_rope();
        self.rx.extend_rope(&delivered, self.ep.ledger());
        let mut resps = Vec::new();
        while let Some(frame) = self.rx.read_frame() {
            if let Some(r) = NetResp::decode(&frame) {
                resps.push(r);
            }
        }
        resps
    }
}

/// The complete DDS storage server: storage path + network path +
/// offload engine + host application.
pub struct DisaggregatedServer<A: HostApp> {
    pub storage: StorageServer,
    pub director: TrafficDirector,
    pub engine: OffloadEngine,
    pub app: A,
    /// Host's endpoint of the PEP's second connection.
    host_ep: TcpEndpoint,
    host_rx: framing::StreamBuf,
}

impl<A: HostApp> DisaggregatedServer<A> {
    pub fn new(
        storage: StorageServer,
        logic: Arc<dyn OffloadLogic>,
        signature: AppSignature,
        engine_cfg: OffloadEngineConfig,
        app: A,
    ) -> Self {
        let mut engine = OffloadEngine::new(
            logic.clone(),
            storage.cache.clone(),
            storage.dpufs.clone(),
            storage.engine_aio(),
            engine_cfg,
        );
        if let Some(tier) = &storage.tier {
            engine.attach_tier(tier.clone());
        }
        let director = TrafficDirector::new(signature, logic, storage.cache.clone());
        DisaggregatedServer {
            storage,
            director,
            engine,
            app,
            host_ep: TcpEndpoint::new(),
            host_rx: framing::StreamBuf::new(),
        }
    }

    /// Build with offloading disabled (baseline mode: everything goes
    /// to the host application).
    pub fn baseline(storage: StorageServer, signature: AppSignature, app: A) -> Self {
        Self::new(
            storage,
            Arc::new(NoOffload),
            signature,
            OffloadEngineConfig::default(),
            app,
        )
    }

    /// Process client packets through the whole server; returns the
    /// segments flowing back to the client. Internally pumps the PEP
    /// host connection and the host application to quiescence.
    pub fn step(&mut self, tuple: &FiveTuple, segs: Vec<Segment>) -> Vec<Segment> {
        let mut to_client = Vec::new();
        let out = self.director.on_client_packets(tuple, segs, &mut self.engine);
        to_client.extend(out.to_client);
        self.pump_host(out.to_host, &mut to_client);
        // Drain engine completions that were in flight.
        let out = self.director.pump_completions(&mut self.engine);
        to_client.extend(out.to_client);
        self.pump_host(out.to_host, &mut to_client);
        to_client
    }

    /// Poll for late engine completions (SSD workers are asynchronous).
    pub fn poll(&mut self) -> Vec<Segment> {
        let mut to_client = Vec::new();
        let out = self.director.pump_completions(&mut self.engine);
        to_client.extend(out.to_client);
        self.pump_host(out.to_host, &mut to_client);
        to_client
    }

    /// Deliver director→host segments into the host app and return its
    /// responses to the director.
    fn pump_host(&mut self, mut to_host: Vec<Segment>, to_client: &mut Vec<Segment>) {
        while !to_host.is_empty() {
            let back_to_dpu =
                host_exchange(&mut self.app, &mut self.host_ep, &mut self.host_rx, &to_host);
            // Feed host segments (ACKs + responses) back to the
            // director.
            let out = self.director.on_host_packets(back_to_dpu);
            to_client.extend(out.to_client);
            to_host = out.to_host;
        }
    }
}

/// Drive a client request fully through a server, waiting for `expect`
/// responses (test/example helper).
pub fn run_request<A: HostApp>(
    client: &mut ClientConn,
    server: &mut DisaggregatedServer<A>,
    msg: &NetMsg,
    timeout: std::time::Duration,
) -> anyhow::Result<Vec<NetResp>> {
    let expect = msg.requests.len();
    let mut out: Vec<NetResp> = Vec::new();
    let mut seen = vec![false; expect];
    let mut wire = client.send_msg(msg);
    let deadline = std::time::Instant::now() + timeout;
    let absorb = |resps: Vec<NetResp>, out: &mut Vec<NetResp>, seen: &mut Vec<bool>| {
        for r in resps {
            // Late/duplicate responses from earlier messages (or TCP
            // retransmits) must not be attributed to this request.
            if r.msg_id != msg.msg_id {
                continue;
            }
            let idx = r.idx as usize;
            if idx < expect && !seen[idx] {
                seen[idx] = true;
                out.push(r);
            }
        }
    };
    loop {
        let back = server.step(&client.tuple, std::mem::take(&mut wire));
        let mut acks = Vec::new();
        let resps = client.on_segments(&back, &mut acks);
        absorb(resps, &mut out, &mut seen);
        wire = acks;
        if out.len() >= expect {
            // Final ACK exchange.
            let _ = server.step(&client.tuple, wire);
            out.sort_by_key(|r| r.idx);
            return Ok(out);
        }
        if wire.is_empty() {
            // Nothing in flight on the wire: wait for async completions.
            let back = server.poll();
            if back.is_empty() {
                std::thread::yield_now();
            }
            let mut acks = Vec::new();
            let resps = client.on_segments(&back, &mut acks);
            absorb(resps, &mut out, &mut seen);
            wire = acks;
        }
        anyhow::ensure!(std::time::Instant::now() < deadline, "request timed out");
    }
}
