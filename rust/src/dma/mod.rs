//! DPU-issued DMA channel model.
//!
//! On real hardware the DPU reads/writes pre-registered host memory over
//! PCIe without host CPU involvement (§4.1). Here host and DPU share one
//! address space, so [`DmaChannel`] is an accounting + latency shim that
//! the DPU-side code wraps around every access to host-resident rings:
//! it counts DMA operations and bytes (the paper's design argues in terms
//! of *number of DMA ops* — e.g. placing the progress pointer before the
//! tail pointer saves one read, §4.1) and can inject a per-op busy-wait
//! so microbenchmarks see a realistic PCIe round-trip cost.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Direction of a DMA operation, for accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaDir {
    /// DPU reads host memory.
    Read,
    /// DPU writes host memory.
    Write,
}

/// Accounting + optional injected latency for DPU-issued DMA.
#[derive(Debug, Default)]
pub struct DmaChannel {
    reads: AtomicU64,
    writes: AtomicU64,
    read_bytes: AtomicU64,
    write_bytes: AtomicU64,
    /// Injected per-op latency in ns (0 = off). Busy-wait, mimicking the
    /// DPU core blocking on the DMA completion.
    op_latency_ns: u64,
}

impl DmaChannel {
    /// A channel with no injected latency (pure accounting).
    pub fn new() -> Self {
        Self::default()
    }

    /// A channel that busy-waits `ns` per DMA op (PCIe round trip).
    pub fn with_latency(ns: u64) -> Self {
        DmaChannel { op_latency_ns: ns, ..Default::default() }
    }

    /// Record one DMA op of `bytes` in direction `dir` (and burn the
    /// injected latency, if configured).
    ///
    /// Scope contract with the buffer plane: this channel meters ONLY
    /// the transfers real hardware would DMA (ring drains/pushes, the
    /// §4.1 op-count arguments). Software copies — the overhead the
    /// zero-copy design eliminates — are metered separately by
    /// [`crate::buf::CopyLedger`]; no byte is ever counted by both.
    #[inline]
    pub fn op(&self, dir: DmaDir, bytes: usize) {
        match dir {
            DmaDir::Read => {
                self.reads.fetch_add(1, Ordering::Relaxed);
                self.read_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
            }
            DmaDir::Write => {
                self.writes.fetch_add(1, Ordering::Relaxed);
                self.write_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
            }
        }
        if self.op_latency_ns > 0 {
            // Busy-wait: Instant-based spin, coarse but monotonic.
            let start = std::time::Instant::now();
            let d = Duration::from_nanos(self.op_latency_ns);
            while start.elapsed() < d {
                std::hint::spin_loop();
            }
        }
    }

    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    pub fn writes(&self) -> u64 {
        self.writes.load(Ordering::Relaxed)
    }

    pub fn ops(&self) -> u64 {
        self.reads() + self.writes()
    }

    pub fn read_bytes(&self) -> u64 {
        self.read_bytes.load(Ordering::Relaxed)
    }

    pub fn write_bytes(&self) -> u64 {
        self.write_bytes.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.reads.store(0, Ordering::Relaxed);
        self.writes.store(0, Ordering::Relaxed);
        self.read_bytes.store(0, Ordering::Relaxed);
        self.write_bytes.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let d = DmaChannel::new();
        d.op(DmaDir::Read, 16);
        d.op(DmaDir::Read, 64);
        d.op(DmaDir::Write, 8);
        assert_eq!(d.reads(), 2);
        assert_eq!(d.writes(), 1);
        assert_eq!(d.ops(), 3);
        assert_eq!(d.read_bytes(), 80);
        assert_eq!(d.write_bytes(), 8);
        d.reset();
        assert_eq!(d.ops(), 0);
    }

    #[test]
    fn injected_latency_burns_time() {
        let d = DmaChannel::with_latency(200_000); // 200 µs, well above timer noise
        let t0 = std::time::Instant::now();
        d.op(DmaDir::Read, 8);
        assert!(t0.elapsed() >= Duration::from_micros(150));
    }
}
