//! E20 — Fig 20: TLDK on the host vs TLDK on the DPU.
//!
//! Paper: "processing large messages with TLDK on the DPU is faster"
//! — the NIC→host round trip is avoided and DPU memory is more
//! efficient for payload processing.

use dds::baselines::netlat::fig20_series;
use dds::metrics::{fmt_ns, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 20 — echo RTT with TLDK: host vs DPU",
        &["msg bytes", "TLDK@host", "TLDK@DPU", "DPU speedup"],
    );
    for (size, host, dpu) in fig20_series(&p) {
        t.row(&[
            size.to_string(),
            fmt_ns(host),
            fmt_ns(dpu),
            format!("{:.2}x", host as f64 / dpu as f64),
        ]);
    }
    t.print();
    println!("\npaper shape: comparable for small messages; DPU wins as payloads grow.");
}
