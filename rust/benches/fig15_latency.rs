//! E15 — Fig 15a/b: achieved throughput vs latency (p50 and p99).
//!
//! Paper anchors: reads — baseline 11 ms @ 390 K IOPS vs DDS offload
//! 780 µs @ 730 K (order of magnitude); DDS files ~6× below baseline.
//! Writes — baseline tail 48 ms @ 210 K; DDS files 3 ms @ 290 K.

use dds::baselines::{run_stack, IoDir, StackKind};
use dds::metrics::{fmt_ns, fmt_ops, Table};
use dds::sim::Params;

fn sweep(dir: IoDir, kinds: &[(StackKind, &str)], p: &Params) {
    let title = match dir {
        IoDir::Read => "Fig 15a — reads (1 KB): throughput vs latency",
        IoDir::Write => "Fig 15b — writes (1 KB): throughput vs latency",
    };
    let mut t = Table::new(title, &["stack", "window", "IOPS", "p50", "p99"]);
    for &(kind, label) in kinds {
        for window in [32usize, 128, 512, 2048, 8192] {
            let r = run_stack(kind, dir, 1024, window, 8, p);
            t.row(&[
                label.to_string(),
                window.to_string(),
                fmt_ops(r.throughput),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p99_ns),
            ]);
        }
    }
    t.print();
}

fn main() {
    let p = Params::paper();
    sweep(
        IoDir::Read,
        &[
            (StackKind::TcpNtfs, "baseline"),
            (StackKind::TcpDds, "DDS file"),
            (StackKind::DdsOffloadTcp, "DDS offload"),
        ],
        &p,
    );
    sweep(
        IoDir::Write,
        &[(StackKind::TcpNtfs, "baseline"), (StackKind::TcpDds, "DDS file")],
        &p,
    );
    println!("\npaper anchors: reads 11ms@390K vs 780µs@730K; writes 48ms tail vs 3ms.");
}
