//! E25 — Fig 25: disaggregated FASTER CPU cost (YCSB uniform reads).
//!
//! Paper: 340 K op/s costs 20 host cores on the baseline; FASTER with
//! DDS achieves 970 K op/s "with zero host CPU investment".

use dds::baselines::appsim::faster_disaggregated;
use dds::metrics::{fmt_ops, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 25 — disaggregated FASTER: throughput vs host CPU cores",
        &["system", "window", "op/s", "host cores"],
    );
    for window in [64usize, 256, 1024, 4096] {
        let (tput, _, _, cores) = faster_disaggregated(window, false, &p);
        t.row(&["baseline".into(), window.to_string(), fmt_ops(tput), format!("{cores:.1}")]);
    }
    for window in [64usize, 256, 1024, 4096] {
        let (tput, _, _, cores) = faster_disaggregated(window, true, &p);
        t.row(&["DDS".into(), window.to_string(), fmt_ops(tput), format!("{cores:.2}")]);
    }
    t.print();
    println!("\npaper anchors: baseline 340K @ 20 cores; DDS 970K @ ~0 host cores.");
}
