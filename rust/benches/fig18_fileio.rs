//! E18 — Fig 18: DPU-backed file I/O throughput, zero-copy vs copy.
//!
//! Paper: "DDS zero-copy design increases file throughput by up to 93%".

use dds::baselines::appsim::fileio_throughput;
use dds::metrics::{fmt_ops, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 18 — DPU file service throughput vs request size",
        &["io bytes", "zero-copy IOPS", "copy IOPS", "gain"],
    );
    for io in [1usize << 10, 4 << 10, 8 << 10, 16 << 10, 32 << 10, 64 << 10] {
        let zc = fileio_throughput(io, true, 512, &p);
        let cp = fileio_throughput(io, false, 512, &p);
        t.row(&[
            io.to_string(),
            fmt_ops(zc),
            fmt_ops(cp),
            format!("{:+.0}%", (zc / cp - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!("\npaper anchor: up to +93% from eliminating staging copies (§4.3).");
}
