//! E19 — Fig 19: efficiency of TLDK for TCP splitting.
//!
//! Paper: Linux TCP on the DPU *offsets* the offloading benefit (worse
//! than host echo); TLDK is ~3× lower latency than Linux-on-DPU and
//! ~2.5× lower than the vanilla host echo.

use dds::baselines::netlat::fig19_series;
use dds::metrics::{fmt_ns, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 19 — echo RTT: host vs DPU(Linux TCP) vs DPU(TLDK)",
        &["msg bytes", "host", "DPU Linux", "DPU TLDK", "TLDK vs Linux", "TLDK vs host"],
    );
    for (size, host, linux, tldk) in fig19_series(&p) {
        t.row(&[
            size.to_string(),
            fmt_ns(host),
            fmt_ns(linux),
            fmt_ns(tldk),
            format!("{:.1}x", linux as f64 / tldk as f64),
            format!("{:.1}x", host as f64 / tldk as f64),
        ]);
    }
    t.print();
    println!("\npaper anchors: Linux-on-DPU > vanilla host; TLDK ≈3x under Linux, ≈2.5x under host.");
}
