//! E24 — Fig 24: Hyperscale page serving, throughput vs latency.
//!
//! Paper: the baseline page server incurs 4.4 ms p99 at 90 K IOPS;
//! with DDS, 160 K IOPS at 1.3 ms p99.

use dds::baselines::appsim::{hyperscale_baseline, pageserver_dds};
use dds::metrics::{fmt_ns, fmt_ops, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 24 — GetPage@LSN (8 KB): throughput vs latency",
        &["system", "window", "pages/s", "p50", "p99", "host cores"],
    );
    for window in [32usize, 128, 512, 1024] {
        let (pt, p50, p99) = hyperscale_baseline(window, &p);
        t.row(&[
            "baseline".into(),
            window.to_string(),
            fmt_ops(pt.throughput),
            fmt_ns(p50),
            fmt_ns(p99),
            format!("{:.1}", pt.total()),
        ]);
    }
    for window in [32usize, 128, 512, 1024] {
        // 95% of pages have fresh-enough cached LSNs (page-server reads
        // are overwhelmingly cold pages, §3).
        let (tput, p50, p99, host_cores) = pageserver_dds(window, 0.95, &p);
        t.row(&[
            "DDS".into(),
            window.to_string(),
            fmt_ops(tput),
            fmt_ns(p50),
            fmt_ns(p99),
            format!("{host_cores:.1}"),
        ]);
    }
    t.print();
    println!("\npaper anchors: baseline 90K @ 4.4ms p99; DDS 160K @ 1.3ms p99.");
}
