//! E02 — Fig 2: CPU cost of the Hyperscale page server for reads.
//!
//! Paper: serving 8 KB page reads costs up to 17 cores at 156 K
//! pages/s, and the DBMS's internal network module is the largest
//! component.

use dds::baselines::appsim::hyperscale_baseline;
use dds::metrics::{fmt_ops, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 2 — Hyperscale page server CPU vs read throughput (8 KB pages)",
        &["pages/s", "dbms-net cores", "os-net cores", "file+other cores", "total"],
    );
    for window in [8usize, 16, 32, 64, 128, 512, 4096] {
        let (pt, _, _) = hyperscale_baseline(window, &p);
        t.row(&[
            fmt_ops(pt.throughput),
            format!("{:.1}", pt.dbms_net_cores),
            format!("{:.1}", pt.os_net_cores),
            format!("{:.1}", pt.file_cores),
            format!("{:.1}", pt.total()),
        ]);
    }
    t.print();
    println!("\npaper anchors: ~17 cores total at ~156K pages/s; DBMS net module largest.");
}
