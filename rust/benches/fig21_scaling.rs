//! E21 — Fig 21: traffic-director scalability with RSS.
//!
//! Paper: "it can direct 6.4 Gbps traffic with a single DPU core and,
//! due to RSS, scale linearly when more cores are added."
//!
//! Also verifies the REAL RSS property on our Toeplitz steering: both
//! directions of a connection land on the same core (symmetric TCP
//! splitting, §7) and flows spread evenly.

use dds::director::rss_core;
use dds::metrics::Table;
use dds::net::FiveTuple;
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 21 — director throughput vs DPU cores (1 KB requests)",
        &["cores", "Gbps"],
    );
    for (cores, gbps) in dds::baselines::netlat::fig21_series(&p, 1024) {
        t.row(&[cores.to_string(), format!("{gbps:.1}")]);
    }
    t.print();

    // Real RSS check: symmetry + spread over 8 cores.
    let cores = 8;
    let mut counts = vec![0usize; cores];
    let mut asym = 0;
    for i in 0..10_000u32 {
        let fwd = FiveTuple::new(0x0a000000 + i, (2000 + i * 13) as u16, 0x0a0000ff, 5000);
        let rev = FiveTuple::new(0x0a0000ff, 5000, 0x0a000000 + i, (2000 + i * 13) as u16);
        let c = rss_core(&fwd, cores);
        if c != rss_core(&rev, cores) {
            asym += 1;
        }
        counts[c] += 1;
    }
    println!("\nRSS (real Toeplitz steering over 10,000 flows, 8 cores):");
    println!("  asymmetric flows : {asym} (must be 0 for split-TCP state locality)");
    println!("  per-core flows   : {counts:?}");
    assert_eq!(asym, 0);
    println!("\npaper anchors: ~6.4 Gbps/core, linear to 8 cores.");
}
