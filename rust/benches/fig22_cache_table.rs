//! E22 / ET2 — Fig 22 + Table 2: cache-table performance (REAL).
//!
//! Paper: ~1.2 M insertions/s with a single writer; 15.7 M lookups/s
//! with eight readers; Table 2 requires millions of op/s for the file
//! service (insert/delete) and offload engine (lookup), tens of
//! millions for the traffic director (lookup).

use std::sync::Arc;
use std::time::{Duration, Instant};

use dds::cache::{CacheItem, CuckooCache};
use dds::metrics::bench::black_box;
use dds::metrics::{fmt_ops, Table};

const RUN: Duration = Duration::from_millis(500);

fn insert_rate(n: usize) -> f64 {
    let t = CuckooCache::new(n * 2);
    let start = Instant::now();
    let mut i = 0u64;
    while start.elapsed() < RUN {
        // Mix of fresh inserts and updates, like cache-on-write traffic.
        t.insert(1 + (i % n as u64), CacheItem::new(i, i + 1, i + 2, i + 3));
        i += 1;
    }
    i as f64 / start.elapsed().as_secs_f64()
}

fn delete_insert_rate(n: usize) -> f64 {
    let t = CuckooCache::new(n * 2);
    for k in 1..=n as u64 {
        t.insert(k, CacheItem::new(k, 0, 0, 0));
    }
    let start = Instant::now();
    let mut i = 0u64;
    while start.elapsed() < RUN {
        let k = 1 + (i % n as u64);
        t.remove(k);
        t.insert(k, CacheItem::new(i, 0, 0, 0));
        i += 2;
    }
    i as f64 / start.elapsed().as_secs_f64()
}

/// Single-reader lookup rate (REAL). Multi-reader numbers are composed
/// as rate × readers: seqlock readers perform no shared writes (no
/// cache-line ping-pong), so scaling is linear — which is also what the
/// paper measures (Fig 22b) — and this container has only one CPU core
/// to measure on (DESIGN.md §1).
fn lookup_rate_single(n: usize) -> f64 {
    let t = Arc::new(CuckooCache::new(n * 2));
    for k in 1..=n as u64 {
        t.insert(k, CacheItem::new(k, k, k, k));
    }
    let start = Instant::now();
    let mut i = 0u64;
    let mut hits = 0u64;
    while start.elapsed() < RUN {
        for _ in 0..64 {
            // ~75% hits, like predicate traffic with cold misses.
            let k = 1 + (i.wrapping_mul(0x9E3779B1) % (n as u64 * 4 / 3));
            if t.get(k).is_some() {
                hits += 1;
            }
            i += 1;
        }
    }
    black_box(hits);
    i as f64 / start.elapsed().as_secs_f64()
}

fn main() {
    let n = 1 << 16;
    let mut t = Table::new(
        "Fig 22 — cache table performance (REAL, 64 K entries)",
        &["operation", "threads", "op/s"],
    );
    let ins = insert_rate(n);
    t.row(&["insert (cache-on-write)".into(), "1".into(), fmt_ops(ins)]);
    let del = delete_insert_rate(n);
    t.row(&["delete+insert".into(), "1".into(), fmt_ops(del)]);
    let lk1 = lookup_rate_single(n);
    let mut lk8 = 0.0;
    for readers in [1usize, 2, 4, 8] {
        let rate = lk1 * readers as f64;
        if readers == 8 {
            lk8 = rate;
        }
        t.row(&["lookup".into(), readers.to_string(), fmt_ops(rate)]);
    }
    t.print();
    println!("(lookup scaling composed from the measured 1-thread rate; single-core container)");

    println!("\nTable 2 targets:");
    println!(
        "  file service insert/delete: millions/s    → measured {} ({})",
        fmt_ops(ins),
        if ins > 1e6 { "MET" } else { "MISSED" }
    );
    println!(
        "  director/engine lookups: 10s of millions  → measured {} ({})",
        fmt_ops(lk8),
        if lk8 > 1e7 { "MET" } else { "MISSED" }
    );
    println!("\npaper anchors: 1.2 M ins/s (1 writer), 15.7 M lookups/s (8 readers).");
}
