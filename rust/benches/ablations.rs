//! Ablations of DDS design choices called out in DESIGN.md (REAL
//! measurements on the functional plane).
//!
//! 1. **Maximum allowable progress (M)** — §4.1's batching knob: DMA
//!    ops per message and message rate vs M.
//! 2. **Cache-table load factor** — lookup rate and chain occupancy as
//!    the table fills (the §6.1 chained-bucket fallback).
//! 3. **Response delivery batch (TailB−TailC threshold)** — §4.3's
//!    batched DMA-write of responses: completion latency vs host-ring
//!    write amortization on the real storage path.

use std::time::{Duration, Instant};

use dds::cache::{CacheItem, CuckooCache};
use dds::coordinator::{StorageServer, StorageServerConfig};
use dds::dma::DmaChannel;
use dds::fileservice::FileServiceConfig;
use dds::metrics::bench::black_box;
use dds::metrics::{fmt_ns, fmt_ops, Table};
use dds::ring::{ProgressRing, RequestRing};

fn ablate_max_progress() {
    let mut t = Table::new(
        "Ablation 1 — max allowable progress M (8 B msgs, REAL)",
        &["M (msgs)", "msgs/s", "DMA ops/msg"],
    );
    for m_msgs in [1usize, 4, 16, 64, 256] {
        let ring = ProgressRing::new(1 << 20, m_msgs * 16);
        let dma = DmaChannel::new();
        let mut sink = 0u64;
        let mut msgs = 0u64;
        let start = Instant::now();
        while start.elapsed() < Duration::from_millis(300) {
            for _ in 0..m_msgs {
                let _ = ring.try_push(&[7u8; 8]);
            }
            msgs += ring.pop_batch_dma(&dma, &mut |m| sink += m[0] as u64) as u64;
        }
        black_box(sink);
        let rate = msgs as f64 / start.elapsed().as_secs_f64();
        t.row(&[
            m_msgs.to_string(),
            fmt_ops(rate),
            format!("{:.2}", dma.ops() as f64 / msgs.max(1) as f64),
        ]);
    }
    t.print();
    println!("larger M amortizes the 3-DMA drain across more messages (§4.1).");
}

fn ablate_load_factor() {
    let mut t = Table::new(
        "Ablation 2 — cache-table load factor (REAL)",
        &["fill %", "items", "chained", "lookups/s"],
    );
    let cap = 1 << 14;
    for fill_pct in [25usize, 50, 75, 100] {
        let table = CuckooCache::new(cap);
        let n = cap * fill_pct / 100;
        for k in 1..=n as u64 {
            table.insert(k, CacheItem::new(k, k, k, k));
        }
        let stats = table.stats();
        let mut hits = 0u64;
        let mut i = 0u64;
        let start = Instant::now();
        while start.elapsed() < Duration::from_millis(300) {
            for _ in 0..64 {
                let k = 1 + (i.wrapping_mul(0x9E3779B1) % (n as u64));
                if table.get(k).is_some() {
                    hits += 1;
                }
                i += 1;
            }
        }
        black_box(hits);
        t.row(&[
            fill_pct.to_string(),
            stats.items.to_string(),
            stats.chain_items.to_string(),
            fmt_ops(i as f64 / start.elapsed().as_secs_f64()),
        ]);
    }
    t.print();
    println!("chains absorb collisions near capacity; lookups stay O(1)-ish (§6.1).");
}

fn ablate_delivery_batch() {
    let mut t = Table::new(
        "Ablation 3 — response delivery batch TailB−TailC (1 KB reads, REAL storage path)",
        &["batch", "IOPS", "p50 per-op wait"],
    );
    for batch in [1usize, 8, 32] {
        let mut cfg = StorageServerConfig::default();
        cfg.service = FileServiceConfig { delivery_batch: batch, ..Default::default() };
        let s = StorageServer::build(cfg, None).unwrap();
        let fe = s.front_end();
        let dir = fe.create_directory("a").unwrap();
        let mut f = fe.create_file(dir, "f").unwrap();
        let g = fe.create_poll().unwrap();
        fe.poll_add(&mut f, &g);
        fe.ensure_size(&f, 8 << 20).unwrap();

        let mut done = 0u64;
        let mut lat = dds::metrics::Histogram::new();
        let start = Instant::now();
        while start.elapsed() < Duration::from_millis(600) {
            // Issue a window of `batch` reads, wait for all.
            let t0 = Instant::now();
            let mut ids: Vec<u64> = Vec::new();
            for i in 0..batch as u64 {
                if let Ok(id) = fe.read_file(&f, (done + i) % 8000 * 1024, 1024) {
                    ids.push(id);
                }
            }
            while !ids.is_empty() {
                for ev in g.poll_wait(Duration::from_millis(20)) {
                    ids.retain(|&x| x != ev.req_id);
                }
            }
            done += batch as u64;
            lat.record(t0.elapsed().as_nanos() as u64 / batch as u64);
        }
        t.row(&[
            batch.to_string(),
            fmt_ops(done as f64 / start.elapsed().as_secs_f64()),
            fmt_ns(lat.p50()),
        ]);
    }
    t.print();
    println!("batched DMA-writes amortize doorbells/poll wakeups; on this host the");
    println!("wakeup cost dominates, so larger batches win on BOTH axes — on real");
    println!("hardware batch=1 would minimize per-op delivery delay (§4.3).");
}

fn main() {
    ablate_max_progress();
    ablate_load_factor();
    ablate_delivery_batch();
}
