//! E04 — Fig 4: responding to TCP messages on host vs on DPU.
//!
//! Paper: "the DPU can halve the latency by avoiding forwarding the
//! message to the host".

use dds::baselines::netlat::fig4_series;
use dds::metrics::{fmt_ns, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 4 — TCP echo round-trip: host responds vs DPU responds",
        &["msg bytes", "host RTT", "DPU RTT", "speedup"],
    );
    for (size, host, dpu) in fig4_series(&p) {
        t.row(&[
            size.to_string(),
            fmt_ns(host),
            fmt_ns(dpu),
            format!("{:.2}x", host as f64 / dpu as f64),
        ]);
    }
    t.print();
    println!("\npaper anchor: DPU roughly halves the round trip across sizes.");
}
