//! Bench-summary emitter: runs the zero-copy ledger probe
//! (`fig23_zerocopy`'s functional half) and the sharded-scaling smoke
//! (`fig21b_sharded_scaling`'s harness at reduced duration) and writes
//! the results to `BENCH_zerocopy.json`; also measures crash-recovery
//! mount latency vs journal chain length into `BENCH_recovery.json` —
//! so CI can archive the perf trajectory of the buffer and durability
//! planes per commit.
//!
//! Smoke mode is the default (seconds, not minutes); tune with:
//!   DDS_BENCH_READS   probe reads per mode        (default 2000)
//!   DDS_BENCH_MS      sharded measure window, ms  (default 300)
//!   DDS_BENCH_SHARDS  comma list of shard counts  (default "1,2")
//!   DDS_BENCH_OUT     output path                 (default BENCH_zerocopy.json)
//!   DDS_BENCH_RECOVERY_OUT  recovery output       (default BENCH_recovery.json)
//!
//! JSON is hand-rolled (no serde in this offline environment): one
//! object with a `zerocopy` section (per-mode ops/s, bytes_copied/req,
//! allocs/req, pool hit rate, plus the copy-reduction ratio vs the
//! straw-man) and a `sharded_scaling` section (ops/s per shard count);
//! the recovery file holds `(syncs, journal_records, mount_us)` points.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dds::apps::RawFileApp;
use dds::coordinator::{
    run_sharded_request, tuple_for_shard, ShardDriver, ShardedServer, ShardedServerConfig,
    StorageServer, StorageServerConfig,
};
use dds::director::AppSignature;
use dds::dpufs::{DpuFs, FsConfig};
use dds::metrics::{probe_engine_read_path, ZeroCopyProbe};
use dds::offload::RawFileOffload;
use dds::ssd::Ssd;
use dds::workload::RandomIoGen;

const FILE_BYTES: u64 = 4 << 20;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One sharded-scaling smoke point (fig21b harness, shorter window).
fn sharded_ops_per_sec(shards: usize, measure: Duration) -> f64 {
    let logic = Arc::new(RawFileOffload);
    let server_cfg = StorageServerConfig { ssd_bytes: 64 << 20, ..Default::default() };
    let storage = StorageServer::build(server_cfg, Some(logic.clone())).expect("storage");
    let file = storage.create_filled_file("bench", "data", FILE_BYTES).expect("fill");
    let fid = file.id.0;
    let cfg = ShardedServerConfig { shards, ..Default::default() };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        |_shard, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");
    let t0 = Instant::now();
    let deadline = t0 + measure;
    let total_ops: u64 = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for s in 0..shards {
            let server = &server;
            handles.push(scope.spawn(move || {
                let mut driver = ShardDriver::new(s);
                let t = tuple_for_shard(
                    s,
                    shards,
                    0x0a00_0001,
                    40_000 + s as u16 * 131,
                    0x0a00_00ff,
                    5000,
                );
                driver.connect(server, t).unwrap();
                let mut gen = RandomIoGen::new(fid, FILE_BYTES, 512, 1.0, 16, 7 + s as u64);
                let mut ops = 0u64;
                while Instant::now() < deadline {
                    let msg = gen.next_msg();
                    match run_sharded_request(server, &mut driver, &t, &msg, Duration::from_secs(5))
                    {
                        Ok(resps) => ops += resps.len() as u64,
                        Err(_) => break,
                    }
                }
                ops
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    total_ops as f64 / t0.elapsed().as_secs_f64()
}

/// One recovery point: format, run `syncs` metadata syncs (each
/// appends a data + commit frame to the journal), then time the
/// recovery mount. Returns `(journal_records_scanned, mean mount µs)`.
fn recovery_point(syncs: usize) -> (usize, f64) {
    let cfg = FsConfig::default(); // 1 MiB segments: journal holds thousands of records
    let ssd = Arc::new(Ssd::new(16 << 20, 512));
    let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).expect("format");
    let d = fs.create_directory("bench").expect("dir");
    for i in 0..8 {
        fs.create_file(d, &format!("f{i}")).expect("file");
    }
    for _ in 0..syncs {
        fs.sync_metadata().expect("sync");
    }
    drop(fs);
    let iters = 20u32;
    let mut scanned = 0usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        let (fs, report) =
            DpuFs::mount_with_report(ssd.clone(), cfg.clone()).expect("recovery mount");
        scanned = report.journal_records;
        drop(fs);
    }
    (scanned, t0.elapsed().as_secs_f64() * 1e6 / iters as f64)
}

fn probe_json(p: &ZeroCopyProbe) -> String {
    format!(
        concat!(
            "{{\"mode\":\"{}\",\"reads\":{},\"read_size\":{},\"ops_per_sec\":{:.1},",
            "\"bytes_copied_per_req\":{:.1},\"allocs_per_req\":{:.3},\"pool_hit_rate\":{:.4}}}"
        ),
        p.mode, p.reads, p.read_size, p.ops_per_sec, p.bytes_copied_per_req,
        p.heap_allocs_per_req, p.pool_hit_rate
    )
}

fn main() {
    let reads = env_u64("DDS_BENCH_READS", 2000);
    let measure = Duration::from_millis(env_u64("DDS_BENCH_MS", 300));
    let shard_list: Vec<usize> = std::env::var("DDS_BENCH_SHARDS")
        .unwrap_or_else(|_| "1,2".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let out_path =
        std::env::var("DDS_BENCH_OUT").unwrap_or_else(|_| "BENCH_zerocopy.json".into());

    eprintln!("bench_summary: zero-copy ledger probe ({reads} reads/mode, 4 KiB)...");
    let zero = probe_engine_read_path(false, reads, 4096, 32);
    let copy = probe_engine_read_path(true, reads, 4096, 32);
    // Copy-reduction ratio vs the straw-man (the pre-buffer-plane
    // equivalent): guard the 0-copy case for a finite JSON number.
    let reduction = if zero.bytes_copied_per_req > 0.0 {
        copy.bytes_copied_per_req / zero.bytes_copied_per_req
    } else if copy.bytes_copied_per_req > 0.0 {
        f64::INFINITY
    } else {
        1.0
    };
    let reduction_str = if reduction.is_finite() {
        format!("{reduction:.1}")
    } else {
        "\"inf\"".to_string()
    };

    let mut sharded = Vec::new();
    for &s in &shard_list {
        eprintln!("bench_summary: sharded smoke at {s} shard(s), {measure:?}...");
        let ops = sharded_ops_per_sec(s, measure);
        sharded.push(format!("{{\"shards\":{s},\"ops_per_sec\":{ops:.1}}}"));
    }

    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"zerocopy\",\n",
            "  \"smoke\": true,\n",
            "  \"zerocopy\": {{\n",
            "    \"zero_copy\": {},\n",
            "    \"copy\": {},\n",
            "    \"bytes_copied_reduction_vs_copy_mode\": {}\n",
            "  }},\n",
            "  \"sharded_scaling\": [{}]\n",
            "}}\n"
        ),
        probe_json(&zero),
        probe_json(&copy),
        reduction_str,
        sharded.join(",")
    );
    std::fs::write(&out_path, &json).expect("write bench summary");
    println!("{json}");
    eprintln!("bench_summary: wrote {out_path}");

    // Durability plane: recovery (mount) time vs journal chain length.
    let recovery_out = std::env::var("DDS_BENCH_RECOVERY_OUT")
        .unwrap_or_else(|_| "BENCH_recovery.json".into());
    let mut points = Vec::new();
    for &syncs in &[1usize, 16, 128, 1024] {
        eprintln!("bench_summary: recovery mount at {syncs} syncs...");
        let (records, mount_us) = recovery_point(syncs);
        points.push(format!(
            "{{\"syncs\":{syncs},\"journal_records\":{records},\"mount_us\":{mount_us:.1}}}"
        ));
    }
    let recovery_json = format!(
        "{{\n  \"bench\": \"recovery\",\n  \"smoke\": true,\n  \"points\": [{}]\n}}\n",
        points.join(",")
    );
    std::fs::write(&recovery_out, &recovery_json).expect("write recovery summary");
    println!("{recovery_json}");
    eprintln!("bench_summary: wrote {recovery_out}");

    // The acceptance contract this PR is gated on (kept as asserts so a
    // regression turns the emitter red even before anyone reads JSON).
    // Each clause is independently binding — no vacuous OR branches:
    // the steady-state zero-copy read path copies NOTHING and
    // allocates NOTHING, and the straw-man provably pays at least the
    // 4 KiB response copy (which also proves the ledger is wired).
    assert_eq!(
        zero.bytes_copied_per_req, 0.0,
        "zero-copy read path memcpy'd bytes (got {} B/req)",
        zero.bytes_copied_per_req
    );
    assert_eq!(zero.heap_allocs_per_req, 0.0, "zero-copy read path allocated on the heap");
    assert!(
        copy.bytes_copied_per_req >= 4096.0,
        "copy-mode ledger under-reports: {} B/req (< one 4 KiB response copy) — \
         is the ledger still wired?",
        copy.bytes_copied_per_req
    );
}
