//! Bench-summary emitter: runs the zero-copy ledger probe
//! (`fig23_zerocopy`'s functional half) and the sharded-scaling smoke
//! (`fig21b_sharded_scaling`'s harness at reduced duration) and writes
//! the results to `BENCH_zerocopy.json`; measures crash-recovery
//! mount latency vs journal chain length into `BENCH_recovery.json`;
//! and meters the CPU plane — busy fraction and ops/s for
//! `IdlePolicy::Poll` vs `Adaptive` at idle / moderate / saturating
//! load (the functional Fig 14 analogue) — into `BENCH_cpu.json`; and
//! records the burst pipeline's tail-latency trajectory (director
//! p50/p99/p99.9 at the same three load levels) into
//! `BENCH_latency.json`; and sweeps the fanout plane — ops/s, director
//! p99 and post-workload idle busy fraction at 100 / 1k / 10k
//! concurrent flows over a zipfian 8-tenant mix — into
//! `BENCH_fanout.json`; and sweeps the caching plane — steady-state
//! zipfian hit ratio, ops/s and bytes served from the DPU read-cache
//! tier at three tier sizes, with the copy ledger proving the hit path
//! is zero-copy — into `BENCH_cache.json`, so CI can archive the perf
//! trajectory of all six planes per commit.
//!
//! Smoke mode is the default (seconds, not minutes); tune with:
//!   DDS_BENCH_READS   probe reads per mode        (default 2000)
//!   DDS_BENCH_MS      sharded measure window, ms  (default 300)
//!   DDS_BENCH_SHARDS  comma list of shard counts  (default "1,2")
//!   DDS_BENCH_OUT     output path                 (default target/BENCH_zerocopy.json)
//!   DDS_BENCH_RECOVERY_OUT  recovery output       (default target/BENCH_recovery.json)
//!   DDS_BENCH_WRITE_MS  durable-WRITE rate window, ms (default 200)
//!   DDS_BENCH_CPU_MS  cpu-plane window, ms        (default 400)
//!   DDS_BENCH_CPU_OUT cpu-plane output            (default target/BENCH_cpu.json)
//!   DDS_BENCH_LAT_MS  latency window per phase, ms (default 400)
//!   DDS_BENCH_LATENCY_OUT  latency output         (default target/BENCH_latency.json)
//!   DDS_BENCH_LAT_CEILING_US  p99 ceiling for the un-queued latency
//!                       phases, µs (default 200000)
//!   DDS_BENCH_FANOUT_FLOWS  comma list of flow counts (default "100,1000,10000")
//!   DDS_BENCH_FANOUT_OUT    fanout output            (default target/BENCH_fanout.json)
//!   DDS_BENCH_CACHE_MB    comma list of tier sizes, MiB (default "1,2,8")
//!   DDS_BENCH_CACHE_READS measured reads per tier size  (default 6000)
//!   DDS_BENCH_CACHE_OUT   cache output              (default target/BENCH_cache.json)
//!   DDS_BENCH_STRICT=1  make the CPU-plane and latency shape checks
//!                       fatal (idle busy fractions, 5% saturated
//!                       parity, latency p99 ceiling); default is
//!                       warn-only so noisy runners never lose the
//!                       artifacts
//!
//! Outputs default under target/ so a local `cargo bench` never
//! dirties the tracked repo-root copies (which only the CI job — with
//! the env vars pinned to the root names — refreshes and commits).
//!
//! JSON is hand-rolled (no serde in this offline environment): one
//! object with a `zerocopy` section (per-mode ops/s, bytes_copied/req,
//! allocs/req, pool hit rate, plus the copy-reduction ratio vs the
//! straw-man) and a `sharded_scaling` section (ops/s per shard count);
//! the recovery file holds `(syncs, journal_records, mount_us)` points
//! plus the data-path columns: `(remaps, mount_us)` dirty-extent replay
//! points and the durable-vs-default acked-WRITE rate.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dds::apps::RawFileApp;
use dds::coordinator::{
    run_sharded_request, tuple_for_shard, ClientConn, ShardDriver, ShardedServer,
    ShardedServerConfig, StorageServer, StorageServerConfig,
};
use dds::director::{AppSignature, TenantPlaneConfig};
use dds::dpufs::{DpuFs, FsConfig};
use dds::fileservice::FileServiceConfig;
use dds::idle::IdlePolicy;
use dds::metrics::{
    probe_cache_tier, probe_engine_read_path, CacheTierProbe, CpuStats, ZeroCopyProbe,
};
use dds::net::FiveTuple;
use dds::offload::RawFileOffload;
use dds::proto::{AppRequest, NetMsg, NetResp};
use dds::sim::Rng;
use dds::ssd::Ssd;
use dds::workload::RandomIoGen;

const FILE_BYTES: u64 = 4 << 20;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One sharded-scaling smoke point (fig21b harness, shorter window).
fn sharded_ops_per_sec(shards: usize, measure: Duration) -> f64 {
    let logic = Arc::new(RawFileOffload);
    let server_cfg = StorageServerConfig { ssd_bytes: 64 << 20, ..Default::default() };
    let storage = StorageServer::build(server_cfg, Some(logic.clone())).expect("storage");
    let file = storage.create_filled_file("bench", "data", FILE_BYTES).expect("fill");
    let fid = file.id.0;
    let cfg = ShardedServerConfig { shards, ..Default::default() };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        |_shard, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");
    let t0 = Instant::now();
    let deadline = t0 + measure;
    let total_ops: u64 = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for s in 0..shards {
            let server = &server;
            handles.push(scope.spawn(move || {
                let mut driver = ShardDriver::new(s);
                let t = tuple_for_shard(
                    s,
                    shards,
                    0x0a00_0001,
                    40_000 + s as u16 * 131,
                    0x0a00_00ff,
                    5000,
                );
                driver.connect(server, t).unwrap();
                let mut gen = RandomIoGen::new(fid, FILE_BYTES, 512, 1.0, 16, 7 + s as u64);
                let mut ops = 0u64;
                while Instant::now() < deadline {
                    let msg = gen.next_msg();
                    match run_sharded_request(server, &mut driver, &t, &msg, Duration::from_secs(5))
                    {
                        Ok(resps) => ops += resps.len() as u64,
                        Err(_) => break,
                    }
                }
                ops
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    total_ops as f64 / t0.elapsed().as_secs_f64()
}

/// One recovery point: format, run `syncs` metadata syncs (each
/// appends a data + commit frame to the journal), then time the
/// recovery mount. Returns `(journal_records_scanned, mean mount µs)`.
fn recovery_point(syncs: usize) -> (usize, f64) {
    let cfg = FsConfig::default(); // 1 MiB segments: journal holds thousands of records
    let ssd = Arc::new(Ssd::new(16 << 20, 512));
    let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).expect("format");
    let d = fs.create_directory("bench").expect("dir");
    for i in 0..8 {
        fs.create_file(d, &format!("f{i}")).expect("file");
    }
    for _ in 0..syncs {
        fs.sync_metadata().expect("sync");
    }
    drop(fs);
    let iters = 20u32;
    let mut scanned = 0usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        let (fs, report) =
            DpuFs::mount_with_report(ssd.clone(), cfg.clone()).expect("recovery mount");
        scanned = report.journal_records;
        drop(fs);
    }
    (scanned, t0.elapsed().as_secs_f64() * 1e6 / iters as f64)
}

/// One data-path recovery point: a base image plus `remaps` committed
/// durable WRITEs still live in the journal (dirty extents the mount
/// must replay onto the file mapping), then time the recovery mount.
/// Returns `(remaps_applied, mean mount µs)`.
fn data_recovery_point(remaps: usize) -> (usize, f64) {
    // 64 KiB segments: cheap shadow pre-images, hundreds of remap
    // records before the journal wraps (a wrap checkpoint would
    // supersede the records and zero the replay count).
    let cfg = FsConfig { segment_size: 1 << 16 };
    let ssd = Arc::new(Ssd::new(16 << 20, 512));
    let mut fs = DpuFs::format(ssd.clone(), cfg.clone()).expect("format");
    let d = fs.create_directory("bench").expect("dir");
    let f = fs.create_file(d, "data").expect("file");
    fs.write_durable(f, 0, &vec![7u8; 1 << 16]).expect("base image");
    for i in 0..remaps {
        fs.write_durable(f, (i % 16) as u64 * 64, &[i as u8; 64]).expect("remap");
    }
    drop(fs);
    let iters = 20u32;
    let mut applied = 0usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        let (fs, report) =
            DpuFs::mount_with_report(ssd.clone(), cfg.clone()).expect("recovery mount");
        applied = report.remaps_applied;
        drop(fs);
    }
    (applied, t0.elapsed().as_secs_f64() * 1e6 / iters as f64)
}

/// Acked-WRITE rate through the full file service with the data path
/// durable or not — the cost of moving the ack point from "payload
/// landed" to "remap record journaled" (shadow pre-image + trailer +
/// append per WRITE).
fn write_rate_point(durable: bool, window: Duration) -> f64 {
    let storage = StorageServer::build(
        StorageServerConfig {
            ssd_bytes: 64 << 20,
            service: FileServiceConfig { durable_data: durable, ..Default::default() },
            ..Default::default()
        },
        None,
    )
    .expect("storage");
    let fe = storage.front_end();
    let dir = fe.create_directory("bench").expect("dir");
    let mut f = fe.create_file(dir, "w").expect("file");
    let group = fe.create_poll().expect("group");
    fe.poll_add(&mut f, &group);
    let data = vec![0x5Au8; 4096];
    let deadline = Instant::now() + window;
    let t0 = Instant::now();
    let (mut ops, mut offset) = (0u64, 0u64);
    while Instant::now() < deadline {
        let id = fe.write_file(&f, offset, &data).expect("write submit");
        'wait: loop {
            for ev in group.poll_wait(Duration::from_millis(10)) {
                if ev.req_id == id {
                    assert!(ev.ok, "bench write failed");
                    break 'wait;
                }
            }
        }
        ops += 1;
        offset = (offset + 4096) % (4 << 20);
    }
    ops as f64 / t0.elapsed().as_secs_f64()
}

/// Aggregate busy fraction across pumps over a window.
fn busy_fraction_delta(before: &[CpuStats], after: &[CpuStats]) -> f64 {
    let (mut busy, mut total) = (0u64, 0u64);
    for (b, a) in before.iter().zip(after) {
        let d = a.since(b);
        busy += d.busy_ns;
        total += d.busy_ns + d.parked_ns;
    }
    if total == 0 {
        1.0
    } else {
        busy as f64 / total as f64
    }
}

/// What one idle policy measured at the three load points.
struct CpuPoint {
    policy: &'static str,
    idle_busy: f64,
    moderate_busy: f64,
    moderate_ops: f64,
    saturated_busy: f64,
    saturated_ops: f64,
}

/// The Fig 14 analogue for one policy: one shard + the file service,
/// measured idle (no traffic), at moderate paced load, and saturated
/// (closed loop).
fn cpu_policy_point(policy: IdlePolicy, label: &'static str, window: Duration) -> CpuPoint {
    let logic = Arc::new(RawFileOffload);
    let mut server_cfg = StorageServerConfig { ssd_bytes: 64 << 20, ..Default::default() };
    server_cfg.service.idle = policy;
    let storage = StorageServer::build(server_cfg, Some(logic.clone())).expect("storage");
    let file = storage.create_filled_file("bench", "data", FILE_BYTES).expect("fill");
    let fid = file.id.0;
    let cfg = ShardedServerConfig { shards: 1, idle: policy, ..Default::default() };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        |_shard, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");
    let mut driver = ShardDriver::new(0);
    let tuple = tuple_for_shard(0, 1, 0x0a00_0001, 40_000, 0x0a00_00ff, 5000);
    driver.connect(&server, tuple).unwrap();
    let mut gen = RandomIoGen::new(fid, FILE_BYTES, 4096, 1.0, 8, 99);

    // Idle: no traffic at all for the window.
    let before = server.all_cpu_stats();
    std::thread::sleep(window);
    let idle_busy = busy_fraction_delta(&before, &server.all_cpu_stats());

    // Moderate: one 8-read batch every ~2 ms.
    let before = server.all_cpu_stats();
    let t0 = Instant::now();
    let mut moderate_ops = 0u64;
    while t0.elapsed() < window {
        let msg = gen.next_msg();
        let r = run_sharded_request(&server, &mut driver, &tuple, &msg, Duration::from_secs(5))
            .expect("moderate request");
        moderate_ops += r.len() as u64;
        std::thread::sleep(Duration::from_millis(2));
    }
    let moderate_busy = busy_fraction_delta(&before, &server.all_cpu_stats());
    let moderate_rate = moderate_ops as f64 / t0.elapsed().as_secs_f64();

    // Saturating: closed loop, no pacing.
    let before = server.all_cpu_stats();
    let t0 = Instant::now();
    let mut sat_ops = 0u64;
    while t0.elapsed() < window {
        let msg = gen.next_msg();
        let r = run_sharded_request(&server, &mut driver, &tuple, &msg, Duration::from_secs(5))
            .expect("saturating request");
        sat_ops += r.len() as u64;
    }
    let saturated_busy = busy_fraction_delta(&before, &server.all_cpu_stats());
    let saturated_ops = sat_ops as f64 / t0.elapsed().as_secs_f64();

    CpuPoint {
        policy: label,
        idle_busy,
        moderate_busy,
        moderate_ops: moderate_rate,
        saturated_busy,
        saturated_ops,
    }
}

/// One load phase of the tail-latency trajectory.
struct LatencyPoint {
    phase: &'static str,
    count: u64,
    mean_ns: f64,
    p50_ns: u64,
    p99_ns: u64,
    p999_ns: u64,
    max_ns: u64,
    ops_per_sec: f64,
}

/// The tail-latency trajectory: per-request service latency at the
/// director (admission → response framing) over one shard + the file
/// service, metered at idle (sparse single reads), moderate (paced
/// 8-read batches) and saturating (closed-loop) load. Each phase is a
/// snapshot window — `LatencySnapshot::since` isolates the phase from
/// everything recorded before it.
fn latency_profile(window: Duration) -> Vec<LatencyPoint> {
    let logic = Arc::new(RawFileOffload);
    let server_cfg = StorageServerConfig { ssd_bytes: 64 << 20, ..Default::default() };
    let storage = StorageServer::build(server_cfg, Some(logic.clone())).expect("storage");
    let file = storage.create_filled_file("bench", "data", FILE_BYTES).expect("fill");
    let fid = file.id.0;
    let cfg = ShardedServerConfig { shards: 1, ..Default::default() };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        |_shard, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");
    let mut driver = ShardDriver::new(0);
    let tuple = tuple_for_shard(0, 1, 0x0a00_0001, 40_000, 0x0a00_00ff, 5000);
    driver.connect(&server, tuple).unwrap();

    let mut points = Vec::new();
    // (phase, reads per message, inter-message pacing)
    let phases: [(&'static str, usize, Option<Duration>); 3] = [
        ("idle", 1, Some(Duration::from_millis(10))),
        ("moderate", 8, Some(Duration::from_millis(2))),
        ("saturating", 8, None),
    ];
    for (phase, batch, pace) in phases {
        let mut gen = RandomIoGen::new(fid, FILE_BYTES, 4096, 1.0, batch, 1234);
        let before = server.latency_snapshot();
        let t0 = Instant::now();
        let mut ops = 0u64;
        while t0.elapsed() < window {
            let msg = gen.next_msg();
            let r = run_sharded_request(&server, &mut driver, &tuple, &msg, Duration::from_secs(5))
                .expect("latency phase request");
            ops += r.len() as u64;
            if let Some(p) = pace {
                std::thread::sleep(p);
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let delta = server.latency_snapshot().since(&before);
        let s = delta.stats();
        points.push(LatencyPoint {
            phase,
            count: s.count,
            mean_ns: s.mean_ns,
            p50_ns: s.p50_ns,
            p99_ns: s.p99_ns,
            p999_ns: s.p999_ns,
            max_ns: s.max_ns,
            ops_per_sec: ops as f64 / elapsed,
        });
    }
    points
}

/// One fanout-plane point: what `flows` concurrent connections over
/// the zipfian tenant mix measured.
struct FanoutPoint {
    flows: usize,
    requests: u64,
    ops_per_sec: f64,
    p50_ns: u64,
    p99_ns: u64,
    /// Busy fraction over a post-workload window with every flow still
    /// open — the "open-but-quiet flows must be free" axis.
    idle_busy: f64,
    starved_tenants: usize,
}

const FANOUT_TENANTS: u32 = 8;

/// Zipfian-ish tenant mix (tenant `r` drawn with weight ∝ 1/(r+1)),
/// mirroring the fanout fairness suite: the tenant plane keys on
/// `client_ip % tenants`, so IP `0x0a00_0000 + t` bills tenant `t`.
fn fanout_ips(n: usize, seed: u64) -> Vec<u32> {
    let weights: Vec<u64> = (0..FANOUT_TENANTS as u64).map(|r| 840 / (r + 1)).collect();
    let total: u64 = weights.iter().sum();
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut draw = rng.next_range(total);
            let mut tenant = FANOUT_TENANTS - 1;
            for (r, &w) in weights.iter().enumerate() {
                if draw < w {
                    tenant = r as u32;
                    break;
                }
                draw -= w;
            }
            0x0a00_0000u32 + tenant
        })
        .collect()
}

/// One connection's client-side state in the fanout sweep.
struct FanoutConn {
    tuple: FiveTuple,
    client: ClientConn,
    outstanding: usize,
}

/// The fanout sweep at one flow count: open `flows` connections spread
/// over the zipfian 8-tenant mix with skewed fair-drain weights, drive
/// batched reads on every flow to completion, and measure ops/s +
/// director latency — then a quiet window with every flow still open,
/// where the readiness plane must keep the pumps parked.
fn fanout_point(flows: usize) -> FanoutPoint {
    let shards = 2usize;
    let batch = 4usize;
    // ~4k requests per point, but never fewer than one full round so
    // every flow sends (at 10k flows one round is already 40k reads).
    let rounds = (4000 / (flows * batch)).max(1);
    let logic = Arc::new(RawFileOffload);
    let server_cfg = StorageServerConfig { ssd_bytes: 64 << 20, ..Default::default() };
    let storage = StorageServer::build(server_cfg, Some(logic.clone())).expect("storage");
    let file = storage.create_filled_file("bench", "data", FILE_BYTES).expect("fill");
    let fid = file.id.0;
    let cfg = ShardedServerConfig {
        shards,
        tenants: TenantPlaneConfig {
            tenants: FANOUT_TENANTS,
            weights: vec![4, 2, 1, 1, 1, 1, 1, 1],
            // No mid-run eviction: every flow stays open through the
            // idle window (which measures open-but-quiet cost).
            flow_ttl_ms: 3_600_000,
            ..Default::default()
        },
        ..Default::default()
    };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        |_shard, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");

    // Connection build-out: unique tuples (port hints collide at high
    // fanout, so dedup explicitly), round-robin over shards.
    let ips = fanout_ips(flows, 0xFA00 ^ flows as u64);
    let mut used = std::collections::HashSet::new();
    let mut per_shard = vec![0usize; shards];
    let mut conns: Vec<FanoutConn> = (0..flows)
        .map(|ci| {
            let s = ci % shards;
            per_shard[s] += 1;
            let mut hint = 40_000u16.wrapping_add((ci as u16).wrapping_mul(101));
            let tuple = loop {
                let t = tuple_for_shard(s, shards, ips[ci], hint, 0x0a00_00ff, 5000);
                if used.insert(t) {
                    break t;
                }
                hint = hint.wrapping_add(1);
            };
            FanoutConn { tuple, client: ClientConn::new(tuple), outstanding: 0 }
        })
        .collect();
    let index: HashMap<FiveTuple, usize> =
        conns.iter().enumerate().map(|(i, c)| (c.tuple, i)).collect();

    let lat_before = server.latency_snapshot();
    let t0 = Instant::now();
    let mut resps_total = 0u64;
    for round in 0..rounds {
        for (ci, c) in conns.iter_mut().enumerate() {
            let msg_id = (round * flows + ci) as u64 + 1;
            let requests = (0..batch)
                .map(|k| {
                    let offset = msg_id
                        .wrapping_mul(7919)
                        .wrapping_add(k as u64)
                        .wrapping_mul(4096)
                        % (FILE_BYTES - 4096);
                    AppRequest::Read { file_id: fid, offset, size: 4096 }
                })
                .collect();
            let segs = c.client.send_msg(&NetMsg { msg_id, requests });
            server.send(&c.tuple, segs).expect("fanout send");
            c.outstanding = batch;
        }
        // Drain the round: receives are per shard, routed to the
        // owning flow by tuple (O(1) per event — a linear scan would
        // be quadratic at 10k flows).
        let mut unresolved = per_shard.clone();
        let deadline = Instant::now() + Duration::from_secs(120);
        while unresolved.iter().any(|&u| u > 0) {
            for shard in 0..shards {
                if unresolved[shard] == 0 {
                    continue;
                }
                if let Some((tuple, segs)) =
                    server.recv_timeout(shard, Duration::from_millis(5))
                {
                    let c = &mut conns[index[&tuple]];
                    let mut acks = Vec::new();
                    let resps = c.client.on_segments(&segs, &mut acks);
                    if !acks.is_empty() {
                        server.send(&c.tuple, acks).expect("fanout ack");
                    }
                    assert!(resps.len() <= c.outstanding, "fanout: duplicate responses");
                    for r in &resps {
                        assert_eq!(r.status, NetResp::OK, "fanout: fault-free read failed");
                    }
                    resps_total += resps.len() as u64;
                    c.outstanding -= resps.len();
                    if !resps.is_empty() && c.outstanding == 0 {
                        unresolved[shard] -= 1;
                    }
                }
            }
            assert!(
                Instant::now() < deadline,
                "fanout sweep stalled at {flows} flows"
            );
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let lat = server.latency_snapshot().since(&lat_before).stats();

    // Quiet window with all flows still open: the whole point of the
    // readiness plane is that 10k open-but-idle flows cost ~no CPU.
    std::thread::sleep(Duration::from_millis(50));
    let before = server.all_cpu_stats();
    std::thread::sleep(Duration::from_millis(200));
    let idle_busy = busy_fraction_delta(&before, &server.all_cpu_stats());

    let stats = server.stats();
    assert_eq!(stats.flows, flows as u64, "flow table must hold exactly the open flows");
    let tenants = server.tenant_stats();
    let starved_tenants = (0..FANOUT_TENANTS)
        .filter(|&t| !tenants.iter().any(|c| c.tenant == t && c.admitted > 0))
        .count();

    FanoutPoint {
        flows,
        requests: resps_total,
        ops_per_sec: resps_total as f64 / elapsed,
        p50_ns: lat.p50_ns,
        p99_ns: lat.p99_ns,
        idle_busy,
        starved_tenants,
    }
}

fn fanout_point_json(p: &FanoutPoint) -> String {
    format!(
        concat!(
            "{{\"flows\":{},\"requests\":{},\"ops_per_sec\":{:.1},\"p50_ns\":{},",
            "\"p99_ns\":{},\"idle_busy_fraction\":{:.4},\"starved_tenants\":{}}}"
        ),
        p.flows, p.requests, p.ops_per_sec, p.p50_ns, p.p99_ns, p.idle_busy, p.starved_tenants
    )
}

fn latency_point_json(p: &LatencyPoint) -> String {
    format!(
        concat!(
            "{{\"phase\":\"{}\",\"count\":{},\"mean_ns\":{:.1},\"p50_ns\":{},",
            "\"p99_ns\":{},\"p999_ns\":{},\"max_ns\":{},\"ops_per_sec\":{:.1}}}"
        ),
        p.phase, p.count, p.mean_ns, p.p50_ns, p.p99_ns, p.p999_ns, p.max_ns, p.ops_per_sec
    )
}

fn cpu_point_json(p: &CpuPoint) -> String {
    format!(
        concat!(
            "{{\"policy\":\"{}\",\"idle_busy_fraction\":{:.4},",
            "\"moderate_busy_fraction\":{:.4},\"moderate_ops_per_sec\":{:.1},",
            "\"saturated_busy_fraction\":{:.4},\"saturated_ops_per_sec\":{:.1}}}"
        ),
        p.policy, p.idle_busy, p.moderate_busy, p.moderate_ops, p.saturated_busy, p.saturated_ops
    )
}

fn cache_point_json(p: &CacheTierProbe) -> String {
    format!(
        concat!(
            "{{\"cache_mb\":{},\"reads\":{},\"read_size\":{},\"hit_ratio\":{:.4},",
            "\"ops_per_sec\":{:.1},\"bytes_served\":{},\"warm_fraction\":{:.4},",
            "\"bytes_copied\":{},\"heap_allocs\":{}}}"
        ),
        p.cache_bytes >> 20,
        p.reads,
        p.read_size,
        p.hit_ratio,
        p.ops_per_sec,
        p.bytes_served,
        p.warm_fraction,
        p.delta.bytes_copied,
        p.delta.heap_allocs
    )
}

fn probe_json(p: &ZeroCopyProbe) -> String {
    format!(
        concat!(
            "{{\"mode\":\"{}\",\"reads\":{},\"read_size\":{},\"ops_per_sec\":{:.1},",
            "\"bytes_copied_per_req\":{:.1},\"allocs_per_req\":{:.3},\"pool_hit_rate\":{:.4}}}"
        ),
        p.mode, p.reads, p.read_size, p.ops_per_sec, p.bytes_copied_per_req,
        p.heap_allocs_per_req, p.pool_hit_rate
    )
}

fn main() {
    let reads = env_u64("DDS_BENCH_READS", 2000);
    let measure = Duration::from_millis(env_u64("DDS_BENCH_MS", 300));
    let shard_list: Vec<usize> = std::env::var("DDS_BENCH_SHARDS")
        .unwrap_or_else(|_| "1,2".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let out_path =
        std::env::var("DDS_BENCH_OUT").unwrap_or_else(|_| "target/BENCH_zerocopy.json".into());

    eprintln!("bench_summary: zero-copy ledger probe ({reads} reads/mode, 4 KiB)...");
    let zero = probe_engine_read_path(false, reads, 4096, 32);
    let copy = probe_engine_read_path(true, reads, 4096, 32);
    // Copy-reduction ratio vs the straw-man (the pre-buffer-plane
    // equivalent): guard the 0-copy case for a finite JSON number.
    let reduction = if zero.bytes_copied_per_req > 0.0 {
        copy.bytes_copied_per_req / zero.bytes_copied_per_req
    } else if copy.bytes_copied_per_req > 0.0 {
        f64::INFINITY
    } else {
        1.0
    };
    let reduction_str = if reduction.is_finite() {
        format!("{reduction:.1}")
    } else {
        "\"inf\"".to_string()
    };

    let mut sharded = Vec::new();
    for &s in &shard_list {
        eprintln!("bench_summary: sharded smoke at {s} shard(s), {measure:?}...");
        let ops = sharded_ops_per_sec(s, measure);
        sharded.push(format!("{{\"shards\":{s},\"ops_per_sec\":{ops:.1}}}"));
    }

    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"zerocopy\",\n",
            "  \"smoke\": true,\n",
            "  \"zerocopy\": {{\n",
            "    \"zero_copy\": {},\n",
            "    \"copy\": {},\n",
            "    \"bytes_copied_reduction_vs_copy_mode\": {}\n",
            "  }},\n",
            "  \"sharded_scaling\": [{}]\n",
            "}}\n"
        ),
        probe_json(&zero),
        probe_json(&copy),
        reduction_str,
        sharded.join(",")
    );
    std::fs::write(&out_path, &json).expect("write bench summary");
    println!("{json}");
    eprintln!("bench_summary: wrote {out_path}");

    // Durability plane: recovery (mount) time vs journal chain length.
    let recovery_out = std::env::var("DDS_BENCH_RECOVERY_OUT")
        .unwrap_or_else(|_| "target/BENCH_recovery.json".into());
    let mut points = Vec::new();
    for &syncs in &[1usize, 16, 128, 1024] {
        eprintln!("bench_summary: recovery mount at {syncs} syncs...");
        let (records, mount_us) = recovery_point(syncs);
        points.push(format!(
            "{{\"syncs\":{syncs},\"journal_records\":{records},\"mount_us\":{mount_us:.1}}}"
        ));
    }
    // Data-path columns: mount µs vs dirty-extent (live remap) count,
    // and the durable-vs-default acked-WRITE rate through the service.
    let mut data_points = Vec::new();
    for &remaps in &[1usize, 16, 128, 512] {
        eprintln!("bench_summary: recovery mount at {remaps} live remaps...");
        let (applied, mount_us) = data_recovery_point(remaps);
        data_points.push(format!(
            "{{\"remaps\":{remaps},\"remaps_applied\":{applied},\"mount_us\":{mount_us:.1}}}"
        ));
    }
    let write_window = Duration::from_millis(env_u64("DDS_BENCH_WRITE_MS", 200));
    eprintln!("bench_summary: WRITE rate, durable_data off ({write_window:?})...");
    let default_ops = write_rate_point(false, write_window);
    eprintln!("bench_summary: WRITE rate, durable_data on...");
    let durable_ops = write_rate_point(true, write_window);
    let durable_ratio = if default_ops > 0.0 { durable_ops / default_ops } else { 1.0 };
    let recovery_json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"recovery\",\n",
            "  \"smoke\": true,\n",
            "  \"points\": [{}],\n",
            "  \"data_points\": [{}],\n",
            "  \"write_rate\": {{\"default_ops_s\":{:.1},\"durable_ops_s\":{:.1},\"durable_over_default\":{:.4}}}\n",
            "}}\n"
        ),
        points.join(","),
        data_points.join(","),
        default_ops,
        durable_ops,
        durable_ratio
    );
    std::fs::write(&recovery_out, &recovery_json).expect("write recovery summary");
    println!("{recovery_json}");
    eprintln!("bench_summary: wrote {recovery_out}");

    // CPU plane: Poll vs Adaptive at idle / moderate / saturating load
    // (the functional Fig 14 analogue — busy fraction is the "cores
    // burned" axis).
    let cpu_out = std::env::var("DDS_BENCH_CPU_OUT").unwrap_or_else(|_| "target/BENCH_cpu.json".into());
    let cpu_window = Duration::from_millis(env_u64("DDS_BENCH_CPU_MS", 400));
    eprintln!("bench_summary: cpu plane, Poll policy ({cpu_window:?}/load point)...");
    let poll = cpu_policy_point(IdlePolicy::Poll, "poll", cpu_window);
    eprintln!("bench_summary: cpu plane, Adaptive policy...");
    let adaptive = cpu_policy_point(IdlePolicy::default(), "adaptive", cpu_window);
    let sat_ratio = if poll.saturated_ops > 0.0 {
        adaptive.saturated_ops / poll.saturated_ops
    } else {
        1.0
    };
    let cpu_json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"cpu\",\n",
            "  \"smoke\": true,\n",
            "  \"policies\": [\n    {},\n    {}\n  ],\n",
            "  \"adaptive_over_poll_saturated\": {:.4}\n",
            "}}\n"
        ),
        cpu_point_json(&poll),
        cpu_point_json(&adaptive),
        sat_ratio
    );
    std::fs::write(&cpu_out, &cpu_json).expect("write cpu summary");
    println!("{cpu_json}");
    eprintln!("bench_summary: wrote {cpu_out}");

    // Latency plane: the tail-latency trajectory of the burst pipeline
    // (per-request director latency at idle / moderate / saturating
    // load). Records the p50/p99/p99.9 curve CI archives per commit.
    let lat_out = std::env::var("DDS_BENCH_LATENCY_OUT")
        .unwrap_or_else(|_| "target/BENCH_latency.json".into());
    let lat_window = Duration::from_millis(env_u64("DDS_BENCH_LAT_MS", 400));
    eprintln!("bench_summary: latency trajectory ({lat_window:?}/load point)...");
    let lat_points = latency_profile(lat_window);
    let lat_json = format!(
        "{{\n  \"bench\": \"latency\",\n  \"smoke\": true,\n  \"phases\": [\n    {}\n  ]\n}}\n",
        lat_points.iter().map(latency_point_json).collect::<Vec<_>>().join(",\n    ")
    );
    std::fs::write(&lat_out, &lat_json).expect("write latency summary");
    println!("{lat_json}");
    eprintln!("bench_summary: wrote {lat_out}");

    // Fanout plane: the readiness-driven flow table + tenant QoS at
    // DBMS-grade connection counts — ops/s and director p99 at 100 /
    // 1k / 10k concurrent flows over a zipfian 8-tenant mix, plus the
    // post-workload idle busy fraction (ten thousand open-but-quiet
    // flows must not keep a single pump hot).
    let fanout_out = std::env::var("DDS_BENCH_FANOUT_OUT")
        .unwrap_or_else(|_| "target/BENCH_fanout.json".into());
    let fanout_flows: Vec<usize> = std::env::var("DDS_BENCH_FANOUT_FLOWS")
        .unwrap_or_else(|_| "100,1000,10000".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let mut fanout_points = Vec::new();
    for &flows in &fanout_flows {
        eprintln!("bench_summary: fanout plane at {flows} flows...");
        fanout_points.push(fanout_point(flows));
    }
    let fanout_json = format!(
        concat!(
            "{{\n  \"bench\": \"fanout\",\n  \"smoke\": true,\n",
            "  \"tenants\": {},\n  \"points\": [\n    {}\n  ]\n}}\n"
        ),
        FANOUT_TENANTS,
        fanout_points.iter().map(fanout_point_json).collect::<Vec<_>>().join(",\n    ")
    );
    std::fs::write(&fanout_out, &fanout_json).expect("write fanout summary");
    println!("{fanout_json}");
    eprintln!("bench_summary: wrote {fanout_out}");

    // Caching plane: steady-state zipfian hit ratio × ops/s × bytes
    // served from the DPU read-cache tier at three sizes over an
    // 8 MiB working set (the largest holds all of it).
    let cache_out = std::env::var("DDS_BENCH_CACHE_OUT")
        .unwrap_or_else(|_| "target/BENCH_cache.json".into());
    let cache_reads = env_u64("DDS_BENCH_CACHE_READS", 6000);
    let cache_sizes: Vec<u64> = std::env::var("DDS_BENCH_CACHE_MB")
        .unwrap_or_else(|_| "1,2,8".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let mut cache_points = Vec::new();
    for &mb in &cache_sizes {
        eprintln!("bench_summary: cache tier at {mb} MiB ({cache_reads} reads)...");
        cache_points.push(probe_cache_tier(mb << 20, cache_reads, 4096, 32));
    }
    let cache_json = format!(
        "{{\n  \"bench\": \"cache\",\n  \"smoke\": true,\n  \"points\": [\n    {}\n  ]\n}}\n",
        cache_points.iter().map(cache_point_json).collect::<Vec<_>>().join(",\n    ")
    );
    std::fs::write(&cache_out, &cache_json).expect("write cache summary");
    println!("{cache_json}");
    eprintln!("bench_summary: wrote {cache_out}");

    // Shape checks: Poll burns the cores at idle, Adaptive gives them
    // back, and Adaptive's saturated throughput stays within 5% of
    // Poll's. All three are wall-clock measurements that scheduler
    // noise on a shared runner can smear, so by default they WARN —
    // aborting here would also lose the just-written artifacts for the
    // commit (the record-never-gate contract of the CI job). Local
    // runs and dedicated boxes set DDS_BENCH_STRICT=1 to make every
    // violation fatal.
    let strict = std::env::var("DDS_BENCH_STRICT").is_ok_and(|v| v == "1");
    let mut check = |ok: bool, msg: String| {
        if ok {
        } else if strict {
            panic!("bench_summary: {msg}");
        } else {
            eprintln!("bench_summary: WARNING: {msg}");
        }
    };
    check(
        poll.idle_busy > 0.5,
        format!("Poll should busy-poll at idle (busy fraction {:.4})", poll.idle_busy),
    );
    check(
        adaptive.idle_busy < 0.05,
        format!(
            "Adaptive idle busy fraction {:.4} >= 5% — pumps are not parking",
            adaptive.idle_busy
        ),
    );
    check(
        sat_ratio >= 0.95,
        format!(
            "Adaptive saturated throughput {:.1} ops/s is below 95% of Poll's {:.1}",
            adaptive.saturated_ops, poll.saturated_ops
        ),
    );
    // Latency-plane shape: every phase recorded samples, and the
    // un-queued phases stay under a generous wall-clock ceiling (the
    // functional path is µs-scale; the ceiling only catches a pipeline
    // that stalls bursts by whole timer ticks). The saturating phase is
    // a closed loop whose tail is runner-dependent, so it is exempt.
    let ceiling_ns = env_u64("DDS_BENCH_LAT_CEILING_US", 200_000) * 1_000;
    for p in &lat_points {
        check(p.count > 0, format!("latency phase {:?} recorded no samples", p.phase));
        if p.phase != "saturating" {
            check(
                p.p99_ns <= ceiling_ns,
                format!(
                    "latency phase {:?} p99 {} ns exceeds ceiling {} ns",
                    p.phase, p.p99_ns, ceiling_ns
                ),
            );
        }
    }
    // Cache-plane shape: bigger tiers must not hit less (zipf over a
    // fixed working set — CLOCK noise can dent but not invert the
    // curve), and the whole-working-set point must serve everything.
    for w in cache_points.windows(2) {
        check(
            w[1].hit_ratio >= w[0].hit_ratio - 0.02,
            format!(
                "cache sweep not monotone: {} MiB hits {:.4} < {} MiB hits {:.4}",
                w[1].cache_bytes >> 20,
                w[1].hit_ratio,
                w[0].cache_bytes >> 20,
                w[0].hit_ratio
            ),
        );
    }
    if let Some(full) = cache_points.iter().find(|p| p.cache_bytes >= 8 << 20) {
        check(
            full.hit_ratio >= 0.999,
            format!(
                "whole-working-set tier should serve ~every read (hit ratio {:.4})",
                full.hit_ratio
            ),
        );
    }

    // Fanout-plane shape: every point served every tenant, and the
    // readiness plane keeps open-but-idle flows cheap — the busy
    // fraction with the full flow population open but quiet must stay
    // under 5% at every point, including 10k flows.
    for p in &fanout_points {
        check(p.requests > 0, format!("fanout point {} recorded no responses", p.flows));
        check(
            p.starved_tenants == 0,
            format!("fanout point {}: {} tenant(s) starved", p.flows, p.starved_tenants),
        );
        check(
            p.idle_busy < 0.05,
            format!(
                "fanout point {}: idle busy fraction {:.4} >= 5% with all flows open",
                p.flows, p.idle_busy
            ),
        );
    }

    // The acceptance contract this PR is gated on (kept as asserts so a
    // regression turns the emitter red even before anyone reads JSON).
    // Each clause is independently binding — no vacuous OR branches:
    // the steady-state zero-copy read path copies NOTHING and
    // allocates NOTHING, and the straw-man provably pays at least the
    // 4 KiB response copy (which also proves the ledger is wired).
    assert_eq!(
        zero.bytes_copied_per_req, 0.0,
        "zero-copy read path memcpy'd bytes (got {} B/req)",
        zero.bytes_copied_per_req
    );
    assert_eq!(zero.heap_allocs_per_req, 0.0, "zero-copy read path allocated on the heap");
    assert!(
        copy.bytes_copied_per_req >= 4096.0,
        "copy-mode ledger under-reports: {} B/req (< one 4 KiB response copy) — \
         is the ledger still wired?",
        copy.bytes_copied_per_req
    );
    // And the caching plane's acceptance clause: a tier hit is a
    // refcount bump, so the measured window must add zero copied bytes
    // and zero heap allocations at EVERY sweep point (misses ride the
    // pooled zero-copy path; hits must not even touch the pool).
    for p in &cache_points {
        assert_eq!(
            p.delta.bytes_copied, 0,
            "cache sweep at {} MiB copied bytes on the read path: {:?}",
            p.cache_bytes >> 20,
            p.delta
        );
        assert_eq!(
            p.delta.heap_allocs, 0,
            "cache sweep at {} MiB hit the heap: {:?}",
            p.cache_bytes >> 20,
            p.delta
        );
        assert!(p.hit_ratio > 0.0, "tier never hit at {} MiB", p.cache_bytes >> 20);
    }
}
