//! E23 — Fig 23: impact of offload-engine zero-copy on read latency
//! and throughput.
//!
//! Paper: peak throughput 520 K → 730 K IOPS and latency 250 µs →
//! 170 µs at peak when the straw-man's two data copies are eliminated
//! (§6.2, Fig 12).

use dds::baselines::appsim::offload_zero_copy;
use dds::metrics::{fmt_ns, fmt_ops, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 23 — offload engine: zero-copy vs copy (1 KB reads)",
        &["mode", "window", "IOPS", "p50"],
    );
    for window in [64usize, 256, 512] {
        let (zt, zl) = offload_zero_copy(true, window, &p);
        let (ct, cl) = offload_zero_copy(false, window, &p);
        t.row(&["zero-copy".into(), window.to_string(), fmt_ops(zt), fmt_ns(zl)]);
        t.row(&["copy".into(), window.to_string(), fmt_ops(ct), fmt_ns(cl)]);
    }
    t.print();
    println!("\npaper anchors: 520K→730K IOPS; 250µs→170µs at peak.");
}
