//! E23 — Fig 23: impact of offload-engine zero-copy on read latency
//! and throughput.
//!
//! Paper: peak throughput 520 K → 730 K IOPS and latency 250 µs →
//! 170 µs at peak when the straw-man's two data copies are eliminated
//! (§6.2, Fig 12).
//!
//! Two planes:
//! 1. the calibrated testbed reproduction of the figure, and
//! 2. the FUNCTIONAL plane's copy ledger — real bytes through the
//!    offload engine, reporting ops/s, bytes memcpy'd per request and
//!    heap allocations per request for zero-copy vs the straw-man.

use dds::baselines::appsim::offload_zero_copy;
use dds::metrics::{fmt_ns, fmt_ops, probe_engine_read_path, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 23 — offload engine: zero-copy vs copy (1 KB reads)",
        &["mode", "window", "IOPS", "p50"],
    );
    for window in [64usize, 256, 512] {
        let (zt, zl) = offload_zero_copy(true, window, &p);
        let (ct, cl) = offload_zero_copy(false, window, &p);
        t.row(&["zero-copy".into(), window.to_string(), fmt_ops(zt), fmt_ns(zl)]);
        t.row(&["copy".into(), window.to_string(), fmt_ops(ct), fmt_ns(cl)]);
    }
    t.print();
    println!("\npaper anchors: 520K→730K IOPS; 250µs→170µs at peak.");

    // Functional plane: the copy ledger, measured on real bytes.
    let reads = std::env::var("DDS_BENCH_READS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000u64);
    let mut t = Table::new(
        "Fig 23 (functional) — copy ledger, 4 KiB offloaded reads",
        &["mode", "ops/s", "bytes copied/req", "heap allocs/req", "pool hit rate"],
    );
    for copy_mode in [false, true] {
        let pr = probe_engine_read_path(copy_mode, reads, 4096, 32);
        t.row(&[
            pr.mode.into(),
            format!("{:.0}", pr.ops_per_sec),
            format!("{:.0}", pr.bytes_copied_per_req),
            format!("{:.2}", pr.heap_allocs_per_req),
            format!("{:.3}", pr.pool_hit_rate),
        ]);
    }
    t.print();
    println!(
        "\nledger contract: zero-copy steady state = 0 heap allocs, 0 bytes memcpy'd per \
         read; the straw-man pays ≥1 alloc + ≥4096 B per read."
    );
}
