//! E26 — Fig 26: disaggregated FASTER latency (YCSB uniform reads).
//!
//! Paper: the baseline incurs 13 ms median (18 ms p99) at 340 K op/s;
//! DDS keeps latency as low as 300 µs.

use dds::baselines::appsim::faster_disaggregated;
use dds::metrics::{fmt_ns, fmt_ops, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 26 — disaggregated FASTER: throughput vs latency",
        &["system", "window", "op/s", "p50", "p99"],
    );
    for window in [64usize, 256, 1024, 4096] {
        let (tput, p50, p99, _) = faster_disaggregated(window, false, &p);
        t.row(&[
            "baseline".into(),
            window.to_string(),
            fmt_ops(tput),
            fmt_ns(p50),
            fmt_ns(p99),
        ]);
    }
    for window in [64usize, 256, 1024, 4096] {
        let (tput, p50, p99, _) = faster_disaggregated(window, true, &p);
        t.row(&["DDS".into(), window.to_string(), fmt_ops(tput), fmt_ns(p50), fmt_ns(p99)]);
    }
    t.print();
    println!("\npaper anchors: baseline 13ms median / 18ms p99 at 340K; DDS ~300µs.");
}
