//! E21b — functional-plane companion to Fig 21: REAL op/s scaling of
//! the sharded server.
//!
//! Fig 21 is regenerated from the calibrated testbed plane
//! (`fig21_scaling.rs`); this bench drives actual bytes through
//! [`ShardedServer`] — client TCP → RSS steering → per-shard director +
//! offload engine → per-shard SSD queue → framed responses — with one
//! client pipeline per shard, and reports aggregate completed read
//! operations per second at 1/2/4/8 shards.
//!
//! Expectation (the §7 claim, functionally): aggregate op/s grows
//! monotonically 1 → 4 shards; the slope flattens once shard+driver
//! threads exceed the machine's cores.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dds::apps::RawFileApp;
use dds::coordinator::{
    run_sharded_request, tuple_for_shard, ShardDriver, ShardedServer, ShardedServerConfig,
    StorageServer, StorageServerConfig,
};
use dds::director::AppSignature;
use dds::metrics::Table;
use dds::offload::RawFileOffload;
use dds::workload::RandomIoGen;

const FILE_BYTES: u64 = 4 << 20;
const IO_BYTES: u32 = 512;
const BATCH: usize = 16;
const MEASURE: Duration = Duration::from_millis(400);

fn build(shards: usize) -> (ShardedServer, u32) {
    let logic = Arc::new(RawFileOffload);
    let server_cfg = StorageServerConfig { ssd_bytes: 64 << 20, ..Default::default() };
    let storage = StorageServer::build(server_cfg, Some(logic.clone())).expect("storage");
    let file = storage.create_filled_file("bench", "data", FILE_BYTES).expect("fill");
    let fid = file.id.0;
    let cfg = ShardedServerConfig { shards, ..Default::default() };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        |_shard, st| RawFileApp::over(st, &file),
    )
    .expect("sharded server");
    (server, fid)
}

/// Drive one client pipeline per shard for [`MEASURE`]; returns
/// (aggregate ops/s, total offloaded ops from server stats).
fn run_config(shards: usize) -> (f64, u64) {
    let (server, fid) = build(shards);
    let t0 = Instant::now();
    let deadline = t0 + MEASURE;
    let total_ops: u64 = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for s in 0..shards {
            let server = &server;
            handles.push(scope.spawn(move || {
                let mut driver = ShardDriver::new(s);
                let t = tuple_for_shard(
                    s,
                    shards,
                    0x0a00_0001,
                    40_000 + s as u16 * 131,
                    0x0a00_00ff,
                    5000,
                );
                driver.connect(server, t).unwrap();
                let mut gen =
                    RandomIoGen::new(fid, FILE_BYTES, IO_BYTES, 1.0, BATCH, 7 + s as u64);
                let mut ops = 0u64;
                while Instant::now() < deadline {
                    let msg = gen.next_msg();
                    match run_sharded_request(server, &mut driver, &t, &msg, Duration::from_secs(5))
                    {
                        Ok(resps) => ops += resps.len() as u64,
                        Err(_) => break,
                    }
                }
                ops
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let offloaded = server.stats().reqs_offloaded;
    (total_ops as f64 / elapsed, offloaded)
}

fn main() {
    println!(
        "functional sharded server: {} B reads, batch {}, one client pipeline per shard, \
         {} ms per config\n",
        IO_BYTES,
        BATCH,
        MEASURE.as_millis()
    );
    let mut t = Table::new(
        "Fig 21b — ShardedServer aggregate read op/s vs shards (real bytes)",
        &["shards", "ops/s", "scale vs 1"],
    );
    let mut base: Option<f64> = None;
    for shards in [1usize, 2, 4, 8] {
        let (ops_per_s, offloaded) = run_config(shards);
        let b = *base.get_or_insert(ops_per_s);
        t.row(&[
            shards.to_string(),
            format!("{ops_per_s:.0}"),
            format!("{:.2}x", ops_per_s / b),
        ]);
        assert!(offloaded > 0, "no reads offloaded at {shards} shards");
    }
    t.print();
    println!(
        "\npaper anchor: Fig 21 — ~6.4 Gbps per director core, scaling linearly as RSS \
         adds cores (flattens here once threads exceed physical cores)."
    );
}
