//! E16 — Fig 16a/b/c: ten-stack comparison at peak throughput.
//!
//! Peak 1 KB read throughput, total CPU (client + server), and
//! median/tail latency for the ten storage solutions of §8.4.

use dds::baselines::{peak, IoDir, StackKind};
use dds::metrics::{fmt_ns, fmt_ops, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 16 — peak throughput / total CPU / latency at peak (1 KB reads)",
        &["stack", "peak IOPS", "srv cores", "cli cores", "dpu cores", "p50", "p99"],
    );
    for kind in StackKind::ALL {
        let r = peak(kind, IoDir::Read, 1024, 8, &p);
        t.row(&[
            kind.label().to_string(),
            fmt_ops(r.throughput),
            format!("{:.1}", r.server_cores),
            format!("{:.1}", r.client_cores),
            format!("{:.1}", r.dpu_cores),
            fmt_ns(r.p50_ns),
            fmt_ns(r.p99_ns),
        ]);
    }
    t.print();
    println!("\npaper shape: SMB/SMB-Direct lowest; kernel-bypass stacks reach local peak;");
    println!("Redy burns polling cores on both sides; DDS offload ~0 host cores; DDS(RDMA) ≈ local.");
}
