//! EK — L1/L3 bridge micro-bench: the AOT Pallas predicate kernel via
//! PJRT vs the scalar rust path (REAL measurement).
//!
//! Loads `artifacts/predicate.hlo.txt`, builds a real cuckoo table,
//! and measures batched kernel evaluation against per-request scalar
//! lookups. Skips gracefully (exit 0, message) when artifacts are
//! missing so `cargo bench` works before `make artifacts`.

use std::time::Duration;

use dds::cache::{CacheItem, CuckooCache};
use dds::metrics::bench::{black_box, time_for};
use dds::metrics::{fmt_ops, Table};
use dds::runtime::{KernelRuntime, PREDICATE_BATCH, PREDICATE_SLOTS};
use dds::sim::Rng;

fn main() {
    let dir = KernelRuntime::artifacts_dir();
    let mut rt = match KernelRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP kernel_predicate: no PJRT client ({e})");
            return;
        }
    };
    if rt.load_dir(&dir).map(|n| n.is_empty()).unwrap_or(true) {
        println!("SKIP kernel_predicate: no artifacts in {dir:?} — run `make artifacts`");
        return;
    }

    let cache = CuckooCache::new(PREDICATE_SLOTS / 2);
    let mut rng = Rng::new(9);
    let mut pages = Vec::new();
    for _ in 0..PREDICATE_SLOTS / 4 {
        let page = rng.next_range(1 << 40) + 1;
        if cache.insert(page, CacheItem::new(rng.next_range(1000) + 1, 1, page * 8192, 8192)) {
            pages.push(page);
        }
    }
    let dense = cache.export_dense();
    let keys: Vec<u64> = (0..PREDICATE_BATCH)
        .map(|i| {
            if i % 4 == 0 {
                rng.next_range(1 << 40) + (1 << 50) // miss
            } else {
                pages[rng.next_range(pages.len() as u64) as usize]
            }
        })
        .collect();
    let lsns: Vec<u64> = keys.iter().map(|_| rng.next_range(1200)).collect();

    let mut t = Table::new(
        "Predicate evaluation: AOT Pallas kernel (PJRT) vs scalar rust",
        &["path", "batch", "eval/s"],
    );

    let r = time_for(Duration::from_secs(2), |_| {
        black_box(rt.predicate_batch(&dense, &keys, &lsns).unwrap());
    });
    t.row(&[
        "pallas kernel (B=1024)".into(),
        PREDICATE_BATCH.to_string(),
        fmt_ops(r.ops_per_sec() * PREDICATE_BATCH as f64),
    ]);

    let r = time_for(Duration::from_secs(2), |_| {
        let mut offload = 0u64;
        for (k, l) in keys.iter().zip(&lsns) {
            if let Some(item) = cache.get(*k) {
                if item.a >= *l {
                    offload += 1;
                }
            }
        }
        black_box(offload);
    });
    t.row(&[
        "scalar rust".into(),
        PREDICATE_BATCH.to_string(),
        fmt_ops(r.ops_per_sec() * PREDICATE_BATCH as f64),
    ]);
    t.print();

    println!("\nNOTE: the kernel runs in Pallas interpret mode on CPU — wallclock here");
    println!("measures dispatch overhead, not TPU performance. See DESIGN.md §Perf for");
    println!("the VMEM/bandwidth analysis that stands in for real-TPU numbers.");
}
