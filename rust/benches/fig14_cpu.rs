//! E14 — Fig 14a/b: achieved throughput vs host CPU cores consumed.
//!
//! Paper anchors (1 KB random I/O): reads — baseline 10.7 cores @
//! 390 K IOPS; DDS files 6.5 cores @ 580 K; DDS offload ~0 cores @
//! 730 K. Writes — no offload; DDS files still saves >5 cores above
//! 200 K IOPS.

use dds::baselines::{run_stack, IoDir, StackKind};
use dds::metrics::{fmt_ops, Table};
use dds::sim::Params;

fn sweep(dir: IoDir, kinds: &[(StackKind, &str)], p: &Params) {
    let title = match dir {
        IoDir::Read => "Fig 14a — reads (1 KB): throughput vs server CPU cores",
        IoDir::Write => "Fig 14b — writes (1 KB): throughput vs server CPU cores",
    };
    let mut t = Table::new(title, &["stack", "window", "IOPS", "host cores", "dpu cores"]);
    for &(kind, label) in kinds {
        for window in [32usize, 128, 512, 2048] {
            let r = run_stack(kind, dir, 1024, window, 8, p);
            t.row(&[
                label.to_string(),
                window.to_string(),
                fmt_ops(r.throughput),
                format!("{:.2}", r.server_cores),
                format!("{:.2}", r.dpu_cores),
            ]);
        }
    }
    t.print();
}

fn main() {
    let p = Params::paper();
    sweep(
        IoDir::Read,
        &[
            (StackKind::TcpNtfs, "baseline"),
            (StackKind::TcpDds, "DDS file"),
            (StackKind::DdsOffloadTcp, "DDS offload"),
        ],
        &p,
    );
    sweep(
        IoDir::Write,
        &[(StackKind::TcpNtfs, "baseline"), (StackKind::TcpDds, "DDS file")],
        &p,
    );
    println!("\npaper anchors: reads 390K@10.7 / 580K@6.5 / 730K@~0 cores; writes 210K vs 290K, >5 cores saved.");
}
