//! E17 — Fig 17a/b: DMA ring-buffer performance.
//!
//! Two-part methodology (this container exposes a SINGLE CPU core, so
//! parallel speedups cannot be *measured*; see DESIGN.md §1):
//!
//! 1. REAL single-threaded measurement of each design's per-message
//!    costs: producer push, consumer drain, and — crucially — the DMA
//!    operations per message (the paper's whole argument): the progress
//!    ring moves a batch with 3 DMA ops, FaRM-style pays ≥2 DMA ops
//!    *per message* plus empty polls, the locked ring batches but
//!    serializes producers.
//! 2. The measured constants + the calibrated PCIe round-trip feed the
//!    queueing testbed to produce the Fig 17 curves (throughput and
//!    median latency vs producer count).
//!
//! Paper anchors (8 B messages): FaRM-style peaks at 64 K op/s;
//! lock-based 22 M at 1 producer → 1.4 M at 64; progress ring 6.5 M at
//! 64 producers (10× / 4.5× better).

use std::time::Duration;

use dds::dma::DmaChannel;
use dds::metrics::bench::time_for;
use dds::metrics::{fmt_ns, fmt_ops, Table};
use dds::ring::{FarmRing, LockedRing, ProgressRing, RequestRing, RingStatus};
use dds::sim::{Engine, FlowSpec, Params, Stage, StageChain, MS, SEC};

const MSG: [u8; 8] = [7u8; 8];
const BATCH: u64 = 32; // M = 32 messages

/// Measured per-design costs, ns.
#[derive(Debug, Clone, Copy)]
struct Costs {
    /// Producer-side cost to insert one message (uncontended).
    push_ns: u64,
    /// Consumer-side CPU to drain one message, excluding DMA waits.
    drain_ns: u64,
    /// DMA ops per message (fractional for batched designs).
    dma_ops_per_msg: f64,
    /// Serialized producer critical section (lock designs), ns; 0 if
    /// producers don't serialize.
    serial_ns: u64,
}

fn measure_progress() -> Costs {
    let ring = ProgressRing::new(1 << 20, (BATCH * 16) as usize);
    let dma = DmaChannel::new();
    // Alternate fill-batch / drain-batch; attribute costs.
    let mut sink = 0u64;
    let push = time_for(Duration::from_millis(300), |_| {
        if ring.try_push(&MSG) != RingStatus::Ok {
            ring.pop_batch_dma(&dma, &mut |m| sink += m[0] as u64);
        }
    });
    // Pure drain cost: prefill then drain.
    let ring = ProgressRing::new(1 << 20, (BATCH * 16) as usize);
    dma.reset();
    let mut msgs = 0u64;
    let drain = time_for(Duration::from_millis(300), |_| {
        for _ in 0..BATCH {
            let _ = ring.try_push(&MSG);
        }
        msgs += ring.pop_batch_dma(&dma, &mut |m| sink += m[0] as u64) as u64;
    });
    std::hint::black_box(sink);
    let dma_per_msg = dma.ops() as f64 / msgs.max(1) as f64;
    Costs {
        push_ns: push.ns_per_op() as u64,
        drain_ns: (drain.ns_per_op() / BATCH as f64) as u64,
        dma_ops_per_msg: dma_per_msg,
        serial_ns: 0,
    }
}

fn measure_farm() -> Costs {
    let ring = FarmRing::new(1 << 12, 16);
    let dma = DmaChannel::new();
    let mut sink = 0u64;
    let mut msgs = 0u64;
    let r = time_for(Duration::from_millis(300), |_| {
        let _ = ring.try_push(&MSG);
        msgs += ring.pop_one_dma(&dma, &mut |m| sink += m[0] as u64) as u64;
    });
    std::hint::black_box(sink);
    Costs {
        push_ns: (r.ns_per_op() / 2.0) as u64,
        drain_ns: (r.ns_per_op() / 2.0) as u64,
        dma_ops_per_msg: dma.ops() as f64 / msgs.max(1) as f64,
        serial_ns: 0,
    }
}

fn measure_locked() -> Costs {
    let ring = LockedRing::new(1 << 14);
    let mut sink = 0u64;
    let push = time_for(Duration::from_millis(300), |i| {
        if ring.try_push(&MSG) != RingStatus::Ok || i % (BATCH * 4) == 0 {
            ring.pop_batch(&mut |m| sink += m[0] as u64);
        }
    });
    std::hint::black_box(sink);
    let per_op = push.ns_per_op() as u64;
    Costs {
        push_ns: per_op,
        drain_ns: per_op / 4,
        // Consumer drains whole backlog per DMA batch: same 3-op batch
        // pattern as the progress design.
        dma_ops_per_msg: 3.0 / BATCH as f64,
        serial_ns: per_op, // the mutex critical section serializes producers
    }
}

/// Compose the Fig 17 curves on the testbed from measured costs.
fn simulate(c: Costs, producers: usize, p: &Params) -> (f64, u64) {
    let mut e = Engine::new(11).with_warmup(5 * MS);
    // Producer cores: the host has plenty; each producer thread is a
    // flow with one token (it blocks until its message is consumed —
    // closed loop matches the paper's message-exchange benchmark).
    let serial = if c.serial_ns > 0 { Some(e.add_resource("lock", 1)) } else { None };
    // Mutex handoff cost grows with contenders (cache-line bouncing +
    // futex wake chains) — the effect that collapses the lock-based
    // ring in Fig 17a.
    let serial_ns = c.serial_ns * (1 + producers as u64 / 4);
    // The consumer (DPU DMA thread) is one core; per message it pays
    // drain CPU + its share of DMA ops at PCIe latency.
    let consumer = e.add_resource("consumer", 1);
    let dma_ns = (c.dma_ops_per_msg * p.dma_op_ns as f64) as u64;
    let mut flows = Vec::new();
    for _ in 0..producers {
        let chain_serial = serial;
        let push = c.push_ns;
        let drain = c.drain_ns;
        flows.push(FlowSpec::new(1, move |_| {
            let mut st = Vec::new();
            match chain_serial {
                Some(lock) => st.push(Stage::Use { res: lock, ns: serial_ns.max(push) }),
                None => st.push(Stage::Delay(push)),
            }
            st.push(Stage::Use { res: consumer, ns: drain + dma_ns });
            StageChain::new(0, st)
        }));
    }
    let rep = e.run(flows, 1, SEC / 5);
    (rep.total_throughput(), rep.latency[0].p50())
}

fn main() {
    println!("measuring single-threaded ring costs (REAL)…");
    let designs = [
        ("progress-lockfree", measure_progress()),
        ("farm-style", measure_farm()),
        ("lock-based", measure_locked()),
    ];
    let mut tc = Table::new(
        "Measured per-design costs (single core — see bench header)",
        &["design", "push", "drain/msg", "DMA ops/msg", "serialized"],
    );
    for (name, c) in &designs {
        tc.row(&[
            name.to_string(),
            fmt_ns(c.push_ns),
            fmt_ns(c.drain_ns),
            format!("{:.2}", c.dma_ops_per_msg),
            if c.serial_ns > 0 { fmt_ns(c.serial_ns) } else { "no".into() },
        ]);
    }
    tc.print();

    let p = Params::paper();
    let mut t = Table::new(
        "Fig 17a/b — message rate and median transfer time vs producers (composed)",
        &["design", "producers", "msgs/s", "median"],
    );
    for (name, c) in &designs {
        for producers in [1usize, 4, 16, 64] {
            let (ops, p50) = simulate(*c, producers, &p);
            t.row(&[name.to_string(), producers.to_string(), fmt_ops(ops), fmt_ns(p50)]);
        }
    }
    t.print();
    println!("\npaper anchors: farm ≤ ~64K (≥2 DMA round-trips per message);");
    println!("locked collapses under producer contention; progress ring dominates at 64 producers.");
}
