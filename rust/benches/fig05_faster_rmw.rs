//! E05 — Fig 5: FASTER RMW throughput on host vs on DPU.
//!
//! Paper: "FASTER runs up to 4.5× slower on the DPU than on the host
//! and can only scale to 8 threads."

use dds::baselines::appsim::faster_rmw;
use dds::metrics::{fmt_ops, Table};
use dds::sim::Params;

fn main() {
    let p = Params::paper();
    let mut t = Table::new(
        "Fig 5 — FASTER YCSB RMW throughput (op/s)",
        &["threads", "host", "DPU", "host/DPU"],
    );
    for threads in [1usize, 2, 4, 8, 16, 32, 48, 64] {
        let (host, dpu) = faster_rmw(threads, &p);
        t.row(&[
            threads.to_string(),
            fmt_ops(host),
            fmt_ops(dpu),
            format!("{:.1}x", host / dpu),
        ]);
    }
    t.print();
    println!("\npaper anchors: ≤8 DPU threads; up to 4.5x slower per-thread on the DPU.");
}
