"""Layer-1 Pallas kernels for the DDS DPU data path.

Kernels mirror (bit-exactly) the rust DPU components they accelerate:

- ``cuckoo``    — batched two-choice cuckoo-hash lookup over the DPU
                  cache table's dense slot arrays (§6.1).
- ``predicate`` — the GetPage@LSN offload predicate fused on top of the
                  lookup (§9.1): ``offload = found & (cached_lsn >= lsn)``.
- ``checksum``  — Fletcher-style page integrity checksum, the stand-in
                  for the DPU's data-path hardware accelerators (§2).

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls (see DESIGN.md §Hardware-Adaptation).
"""
