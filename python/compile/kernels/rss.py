"""Toeplitz RSS hash, vectorized (§7 core steering).

Pure-jnp (no pallas): the hash is bit-serial by nature; the vectorized
formulation processes a batch of 12-byte normalized flow tuples at
once. Kept build-time only — the rust director has its own scalar
implementation (`rust/src/director/rss.rs`); this module documents the
math and lets pytest cross-check the two (same key, same semantics) so
the steering decision can be batch-evaluated on the DPU data path if a
deployment wants it.
"""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

# The Microsoft RSS reference key — identical to rust/src/director/rss.rs.
KEY = np.array(
    [
        0x6D, 0x5A, 0x56, 0xDA, 0x25, 0x5B, 0x0E, 0xC2, 0x41, 0x67, 0x25, 0x3D, 0x43,
        0xA3, 0x8F, 0xB0, 0xD0, 0xCA, 0x2B, 0xCB, 0xAE, 0x7B, 0x30, 0xB4, 0x77, 0xCB,
        0x2D, 0xA3, 0x80, 0x30, 0xF2, 0x0C, 0x6A, 0x42, 0xB7, 0x3B, 0xBE, 0xAC, 0x01,
        0xFA,
    ],
    dtype=np.uint8,
)


def _key_windows(n_bits: int) -> np.ndarray:
    """32-bit key window for each input bit position (precomputed)."""
    key_bits = np.unpackbits(KEY)
    windows = np.zeros(n_bits, dtype=np.uint64)
    for i in range(n_bits):
        w = 0
        for j in range(32):
            bit = key_bits[i + j] if i + j < len(key_bits) else 0
            w = (w << 1) | int(bit)
        windows[i] = w
    return windows


def toeplitz_hash_batch(tuples_u8: np.ndarray) -> np.ndarray:
    """Hash a batch of byte tuples: uint8[B, N] → uint32[B].

    result[b] = XOR over set bits i of window(i) — the standard Toeplitz
    formulation, vectorized as a masked XOR-reduction.
    """
    tuples_u8 = np.asarray(tuples_u8, dtype=np.uint8)
    b, n = tuples_u8.shape
    bits = np.unpackbits(tuples_u8, axis=1).astype(np.uint64)  # [B, 8N]
    windows = _key_windows(8 * n)  # [8N]
    masked = jnp.asarray(bits) * jnp.asarray(windows)[None, :]
    # XOR-reduce along the bit axis.
    out = jax.lax.reduce(
        masked, jnp.uint64(0), lambda a, c: jnp.bitwise_xor(a, c), dimensions=[1]
    )
    return np.asarray(out, dtype=np.uint64).astype(np.uint32)


def normalize_tuple(client_ip, client_port, server_ip, server_port) -> np.ndarray:
    """Order-normalized 12-byte tuple — both flow directions produce the
    same bytes (symmetric steering, §7); mirrors
    `rust/src/director/rss.rs::rss_core`."""
    a = (int(client_ip), int(client_port))
    b = (int(server_ip), int(server_port))
    lo, hi = (a, b) if a <= b else (b, a)
    out = np.zeros(12, dtype=np.uint8)
    out[0:4] = np.frombuffer(int(lo[0]).to_bytes(4, "big"), dtype=np.uint8)
    out[4:8] = np.frombuffer(int(hi[0]).to_bytes(4, "big"), dtype=np.uint8)
    out[8:10] = np.frombuffer(int(lo[1]).to_bytes(2, "big"), dtype=np.uint8)
    out[10:12] = np.frombuffer(int(hi[1]).to_bytes(2, "big"), dtype=np.uint8)
    return out


def rss_core_batch(tuples, cores: int) -> np.ndarray:
    """Steer a batch of (cip, cport, sip, sport) tuples to cores."""
    normalized = np.stack([normalize_tuple(*t) for t in tuples])
    return toeplitz_hash_batch(normalized).astype(np.uint64) % np.uint64(cores)
