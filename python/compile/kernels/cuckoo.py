"""Pallas kernel: batched two-choice cuckoo lookup (§6.1).

TPU adaptation of the DPU cache-table probe (DESIGN.md
§Hardware-Adaptation): instead of per-packet scalar probes on Arm
cores, the traffic director batches request keys and evaluates one
vectorized lookup. The dense table tile (8192 slots × 8 B keys + 32 B
items ≈ 320 KB) fits comfortably in VMEM; the batch dimension is tiled
by ``BlockSpec`` so each grid step processes ``block_b`` keys.

The kernel is gather/compare-bound — the roofline target is memory
bandwidth, not MXU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import H1_MUL, H1_SHIFT, H2_MUL, H2_SHIFT, H2_XOR_SHIFT, SLOTS

jax.config.update("jax_enable_x64", True)


def _lookup_kernel(tk_ref, ti_ref, keys_ref, found_ref, items_ref):
    """One batch tile: probe both candidate buckets of each key."""
    tk = tk_ref[...]  # [S]           table keys (VMEM-resident tile)
    ti = ti_ref[...]  # [S, 4]        table items
    k = keys_ref[...]  # [Bt]

    nbuckets = tk.shape[0] // SLOTS
    mask = jnp.uint64(nbuckets - 1)
    b1 = (k * H1_MUL >> jnp.uint64(H1_SHIFT)) & mask
    x = k ^ (k >> jnp.uint64(H2_XOR_SHIFT))
    b2 = (x * H2_MUL >> jnp.uint64(H2_SHIFT)) & mask

    offs = jnp.arange(SLOTS, dtype=jnp.uint64)
    # [Bt, 2*SLOTS] flat candidate slots.
    cand = jnp.concatenate(
        [
            b1[:, None] * jnp.uint64(SLOTS) + offs[None, :],
            b2[:, None] * jnp.uint64(SLOTS) + offs[None, :],
        ],
        axis=1,
    ).astype(jnp.int32)
    cand_keys = tk[cand]  # gather [Bt, 8]
    match = cand_keys == k[:, None]
    found = jnp.any(match, axis=1)
    first = jnp.argmax(match, axis=1)
    rows = cand[jnp.arange(cand.shape[0]), first]
    items = ti[rows]  # [Bt, 4]
    items = jnp.where(found[:, None], items, jnp.uint64(0))

    found_ref[...] = found.astype(jnp.uint64)
    items_ref[...] = items


@functools.partial(jax.jit, static_argnames=("block_b",))
def cuckoo_lookup(table_keys, table_items, keys, *, block_b=256):
    """Batched lookup.

    table_keys : uint64[S], table_items: uint64[S,4], keys: uint64[B]
    → (found uint64[B], items uint64[B,4]). B must divide by block_b.
    """
    b = keys.shape[0]
    s = table_keys.shape[0]
    assert b % block_b == 0, f"batch {b} not divisible by block {block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _lookup_kernel,
        grid=grid,
        in_specs=[
            # The table tile is replicated to every grid step (index_map
            # pins block 0) — it lives in VMEM across the whole sweep.
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((s, 4), lambda i: (0, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, 4), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.uint64),
            jax.ShapeDtypeStruct((b, 4), jnp.uint64),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(table_keys, table_items, keys)
