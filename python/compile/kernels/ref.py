"""Pure-numpy correctness oracles for the Pallas kernels.

These are the single source of truth for kernel semantics; pytest
asserts kernel == ref across randomized shapes and values (hypothesis),
and the rust side re-implements the same functions
(``rust/src/cache/table.rs`` hashes, ``rust/src/runtime`` checksum) so
the whole three-layer stack agrees bit-for-bit.
"""

import numpy as np

# Hash constants — keep in sync with rust/src/cache/table.rs.
H1_MUL = np.uint64(0x9E3779B97F4A7C15)
H1_SHIFT = np.uint64(17)
H2_MUL = np.uint64(0xC2B2AE3D27D4EB4F)
H2_SHIFT = np.uint64(13)
H2_XOR_SHIFT = np.uint64(33)

SLOTS = 4
EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)


def h1(keys: np.ndarray, nbuckets: int) -> np.ndarray:
    """First cuckoo bucket index (multiply-shift)."""
    keys = keys.astype(np.uint64)
    with np.errstate(over="ignore"):
        return (keys * H1_MUL >> H1_SHIFT) & np.uint64(nbuckets - 1)


def h2(keys: np.ndarray, nbuckets: int) -> np.ndarray:
    """Second cuckoo bucket index (xor-fold multiply-shift)."""
    keys = keys.astype(np.uint64)
    with np.errstate(over="ignore"):
        x = keys ^ (keys >> H2_XOR_SHIFT)
        return (x * H2_MUL >> H2_SHIFT) & np.uint64(nbuckets - 1)


def cuckoo_lookup_ref(table_keys, table_items, keys):
    """Reference lookup.

    table_keys : uint64[S]      (S = nbuckets * SLOTS; EMPTY = free)
    table_items: uint64[S, 4]
    keys       : uint64[B]

    Returns (found uint64[B], items uint64[B, 4]); items are zero on
    miss.
    """
    table_keys = np.asarray(table_keys, dtype=np.uint64)
    table_items = np.asarray(table_items, dtype=np.uint64)
    keys = np.asarray(keys, dtype=np.uint64)
    nbuckets = table_keys.shape[0] // SLOTS

    b1 = h1(keys, nbuckets)
    b2 = h2(keys, nbuckets)
    offs = np.arange(SLOTS, dtype=np.uint64)
    # [B, 2*SLOTS] candidate flat slot indices.
    cand = np.concatenate(
        [
            (b1[:, None] * np.uint64(SLOTS)) + offs[None, :],
            (b2[:, None] * np.uint64(SLOTS)) + offs[None, :],
        ],
        axis=1,
    ).astype(np.int64)
    cand_keys = table_keys[cand]  # [B, 8]
    match = cand_keys == keys[:, None]
    found = match.any(axis=1)
    first = match.argmax(axis=1)
    items = table_items[cand[np.arange(len(keys)), first]]  # [B, 4]
    items = np.where(found[:, None], items, np.uint64(0))
    return found.astype(np.uint64), items


def predicate_ref(table_keys, table_items, keys, lsns):
    """Reference offload predicate (§9.1).

    Returns (mask, a, b, cd) with cd packing (c, d) as uint64[B, 2] —
    the exact output contract of the AOT `predicate` artifact.
    ``mask = found & (item.a >= lsn)``.
    """
    lsns = np.asarray(lsns, dtype=np.uint64)
    found, items = cuckoo_lookup_ref(table_keys, table_items, keys)
    mask = (found != 0) & (items[:, 0] >= lsns)
    mask64 = mask.astype(np.uint64)
    a = items[:, 0] * mask64
    b = items[:, 1] * mask64
    cd = items[:, 2:4] * mask64[:, None]
    return mask64, a, b, cd


def checksum_ref(pages_u32):
    """Reference Fletcher-style checksum over little-endian u32 words.

    pages_u32: uint32[B, W]. Returns uint64[B]: (s2 << 32) | s1 with
    s1 = sum(w) mod 2^32 and s2 = sum of prefix sums mod 2^32.
    """
    pages = np.asarray(pages_u32, dtype=np.uint64)
    w = pages.shape[1]
    s1 = pages.sum(axis=1) & np.uint64(0xFFFFFFFF)
    weights = np.arange(w, 0, -1, dtype=np.uint64)  # N, N-1, …, 1
    s2 = (pages * weights[None, :]).sum(axis=1) & np.uint64(0xFFFFFFFF)
    return (s2 << np.uint64(32)) | s1


def build_dense_table(entries, nbuckets):
    """Place (key, item) pairs into dense slot arrays using the same
    two-choice discipline as the rust table (slots only, no chains).

    Returns (table_keys uint64[S], table_items uint64[S,4], placed) —
    `placed` lists the entries that fit (the rest would chain on the
    real table and miss in the kernel, which is the documented
    fall-back-to-host behaviour).
    """
    S = nbuckets * SLOTS
    table_keys = np.full(S, EMPTY, dtype=np.uint64)
    table_items = np.zeros((S, 4), dtype=np.uint64)
    placed = []
    for key, item in entries:
        key_arr = np.array([key], dtype=np.uint64)
        done = False
        for b in (int(h1(key_arr, nbuckets)[0]), int(h2(key_arr, nbuckets)[0])):
            for s in range(SLOTS):
                flat = b * SLOTS + s
                if table_keys[flat] == EMPTY:
                    table_keys[flat] = np.uint64(key)
                    table_items[flat] = np.asarray(item, dtype=np.uint64)
                    placed.append((int(key), tuple(int(x) for x in item)))
                    done = True
                    break
            if done:
                break
    return table_keys, table_items, placed
