"""Pallas kernel: fused GetPage@LSN offload predicate (§6.1, §9.1).

Fuses the cuckoo lookup with the freshness check so one kernel sweep
answers, per request: *can the DPU serve this page?* — ``offload =
found & (cached_lsn >= requested_lsn)`` — and if so, where the page
lives (`file_id`, `offset`, `size` from the cache item).

Output contract (matches `rust/src/runtime::predicate_batch`):
    (mask u64[B], a u64[B], b u64[B], cd u64[B,2])
with item words zeroed when ``mask == 0``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import H1_MUL, H1_SHIFT, H2_MUL, H2_SHIFT, H2_XOR_SHIFT, SLOTS

jax.config.update("jax_enable_x64", True)


def _predicate_kernel(tk_ref, ti_ref, keys_ref, lsns_ref, mask_ref, a_ref, b_ref, cd_ref):
    tk = tk_ref[...]
    ti = ti_ref[...]
    k = keys_ref[...]
    lsns = lsns_ref[...]

    nbuckets = tk.shape[0] // SLOTS
    bmask = jnp.uint64(nbuckets - 1)
    b1 = (k * H1_MUL >> jnp.uint64(H1_SHIFT)) & bmask
    x = k ^ (k >> jnp.uint64(H2_XOR_SHIFT))
    b2 = (x * H2_MUL >> jnp.uint64(H2_SHIFT)) & bmask

    offs = jnp.arange(SLOTS, dtype=jnp.uint64)
    cand = jnp.concatenate(
        [
            b1[:, None] * jnp.uint64(SLOTS) + offs[None, :],
            b2[:, None] * jnp.uint64(SLOTS) + offs[None, :],
        ],
        axis=1,
    ).astype(jnp.int32)
    cand_keys = tk[cand]
    match = cand_keys == k[:, None]
    found = jnp.any(match, axis=1)
    first = jnp.argmax(match, axis=1)
    rows = cand[jnp.arange(cand.shape[0]), first]
    items = ti[rows]

    # Fused freshness check: the cached LSN (item word a) must cover the
    # requested LSN.
    fresh = items[:, 0] >= lsns
    mask = jnp.logical_and(found, fresh)
    m64 = mask.astype(jnp.uint64)

    mask_ref[...] = m64
    a_ref[...] = items[:, 0] * m64
    b_ref[...] = items[:, 1] * m64
    cd_ref[...] = items[:, 2:4] * m64[:, None]


@functools.partial(jax.jit, static_argnames=("block_b",))
def offload_predicate(table_keys, table_items, keys, lsns, *, block_b=256):
    """Fused lookup + predicate over a batch of requests."""
    b = keys.shape[0]
    s = table_keys.shape[0]
    assert b % block_b == 0
    grid = (b // block_b,)
    return pl.pallas_call(
        _predicate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((s, 4), lambda i: (0, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.uint64),
            jax.ShapeDtypeStruct((b,), jnp.uint64),
            jax.ShapeDtypeStruct((b,), jnp.uint64),
            jax.ShapeDtypeStruct((b, 2), jnp.uint64),
        ],
        interpret=True,
    )(table_keys, table_items, keys, lsns)
