"""Pallas kernel: Fletcher-style page checksum.

Stand-in for the BF-2 data-path accelerators (§2: "executing
corresponding workloads in hardware accelerators can be orders of
magnitude faster") — the DPU can checksum pages as it serves them.

Math: over little-endian u32 words w_0..w_{N-1},
  s1 = Σ w_i            mod 2^32
  s2 = Σ (N - i) * w_i  mod 2^32     (≡ sum of prefix sums)
result = s2 << 32 | s1.
Deferring the modulo to the end is exact in u64: products ≤ 2^43 and
N ≤ 2^11 keep the accumulation below 2^54.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _checksum_kernel(pages_ref, out_ref):
    pages = pages_ref[...].astype(jnp.uint64)  # [Bt, W]
    w = pages.shape[1]
    s1 = jnp.sum(pages, axis=1) & jnp.uint64(0xFFFFFFFF)
    # Weights N, N-1, …, 1 — generated with iota INSIDE the kernel
    # (pallas rejects captured host constants).
    iota = jax.lax.broadcasted_iota(jnp.uint64, (w,), 0)
    weights = jnp.uint64(w) - iota
    s2 = jnp.sum(pages * weights[None, :], axis=1) & jnp.uint64(0xFFFFFFFF)
    out_ref[...] = (s2 << jnp.uint64(32)) | s1


@functools.partial(jax.jit, static_argnames=("block_b",))
def page_checksum(pages_u32, *, block_b=4):
    """Checksum a batch of pages: uint32[B, W] → uint64[B]."""
    b, w = pages_u32.shape
    assert b % block_b == 0
    grid = (b // block_b,)
    return pl.pallas_call(
        _checksum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, w), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_b,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((b,), jnp.uint64)],
        interpret=True,
    )(pages_u32)[0]
