"""Build-time Python for DDS: Layer-2 JAX models over Layer-1 Pallas
kernels, AOT-lowered to HLO text by ``compile.aot``. Never imported at
runtime — the rust coordinator executes the artifacts via PJRT.
"""
