"""AOT lowering: JAX models → HLO **text** artifacts for the rust PJRT
runtime.

HLO text — not ``lowered.compile()`` serialization — is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --outdir ../artifacts``
The Makefile invokes this once; rust never touches Python again.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


ARTIFACTS = {
    "predicate": (model.predicate_model, model.predicate_example_args),
    "checksum": (model.checksum_model, model.checksum_example_args),
}


def build(outdir: str, only=None) -> list[str]:
    os.makedirs(outdir, exist_ok=True)
    written = []
    for name, (fn, args_fn) in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*args_fn())
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name}: {len(text)} chars -> {path}")
        written.append(path)
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    build(args.outdir, args.only)


if __name__ == "__main__":
    main()
