"""Layer-2 JAX models: the compute graphs the rust coordinator executes.

Each model is a jitted JAX function calling the Layer-1 Pallas kernels;
``compile.aot`` lowers them once to HLO text. Shapes are fixed at AOT
time (PJRT executables are monomorphic); the rust runtime pads batches
to these shapes (see ``rust/src/runtime``).
"""

import jax
import jax.numpy as jnp

from .kernels.checksum import page_checksum
from .kernels.predicate import offload_predicate

jax.config.update("jax_enable_x64", True)

# AOT shapes — keep in sync with rust/src/runtime/mod.rs constants.
PREDICATE_BATCH = 1024
PREDICATE_SLOTS = 8192
PREDICATE_BLOCK = 256
CHECKSUM_BATCH = 16
CHECKSUM_PAGE_WORDS = 8192 // 4
CHECKSUM_BLOCK = 4


def predicate_model(table_keys, table_items, keys, lsns):
    """The traffic-director batch predicate (§5.1/§6.1 on TPU idioms).

    One fused kernel sweep: cuckoo lookup + LSN freshness. Returns the
    4-tuple contract described in ``kernels.predicate``.
    """
    mask, a, b, cd = offload_predicate(
        table_keys, table_items, keys, lsns, block_b=PREDICATE_BLOCK
    )
    return mask, a, b, cd


def checksum_model(pages_u32):
    """Batch page-integrity checksum (accelerator stand-in)."""
    return (page_checksum(pages_u32, block_b=CHECKSUM_BLOCK),)


def predicate_example_args():
    """ShapeDtypeStructs for AOT lowering of the predicate model."""
    u64 = jnp.uint64
    return (
        jax.ShapeDtypeStruct((PREDICATE_SLOTS,), u64),
        jax.ShapeDtypeStruct((PREDICATE_SLOTS, 4), u64),
        jax.ShapeDtypeStruct((PREDICATE_BATCH,), u64),
        jax.ShapeDtypeStruct((PREDICATE_BATCH,), u64),
    )


def checksum_example_args():
    return (jax.ShapeDtypeStruct((CHECKSUM_BATCH, CHECKSUM_PAGE_WORDS), jnp.uint32),)
