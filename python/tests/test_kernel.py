"""Kernel-vs-reference correctness — the CORE L1 signal.

Hypothesis sweeps table sizes, batch/block shapes, and adversarial key
values; every property asserts the Pallas kernel (interpret mode)
matches the pure-numpy oracle exactly (integer kernels → bit equality,
no tolerance needed).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # skip, don't abort collection, when absent
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.checksum import page_checksum
from compile.kernels.cuckoo import cuckoo_lookup
from compile.kernels.predicate import offload_predicate

u64 = np.uint64


def random_table(rng, nbuckets, n_entries):
    keys = rng.choice(np.arange(1, 10 * n_entries + 1, dtype=np.uint64),
                      size=n_entries, replace=False)
    entries = [
        (int(k), (int(rng.integers(0, 2**40)), int(rng.integers(0, 2**32)),
                  int(rng.integers(0, 2**40)), int(rng.integers(1, 2**20))))
        for k in keys
    ]
    tk, ti, placed = ref.build_dense_table(entries, nbuckets)
    return tk, ti, dict(placed)


# ---------------------------------------------------------------- cuckoo

@settings(max_examples=25, deadline=None)
@given(
    nbuckets_log=st.integers(min_value=3, max_value=9),
    batch_log=st.integers(min_value=0, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_cuckoo_lookup_matches_ref(nbuckets_log, batch_log, seed):
    rng = np.random.default_rng(seed)
    nbuckets = 1 << nbuckets_log
    block = 16 << batch_log
    batch = block * int(rng.integers(1, 5))
    tk, ti, placed = random_table(rng, nbuckets, nbuckets * 2)

    present = np.array(list(placed.keys()) or [1], dtype=u64)
    hit_keys = rng.choice(present, size=batch // 2)
    miss_keys = rng.integers(10**12, 10**13, size=batch - batch // 2, dtype=np.uint64)
    keys = np.concatenate([hit_keys, miss_keys]).astype(u64)
    rng.shuffle(keys)

    found_k, items_k = cuckoo_lookup(tk, ti, keys, block_b=block)
    found_r, items_r = ref.cuckoo_lookup_ref(tk, ti, keys)
    np.testing.assert_array_equal(np.asarray(found_k), found_r)
    np.testing.assert_array_equal(np.asarray(items_k), items_r)


def test_cuckoo_lookup_semantics_against_placed_entries():
    rng = np.random.default_rng(7)
    tk, ti, placed = random_table(rng, 64, 128)
    keys = np.array(list(placed.keys()), dtype=u64)
    pad = (-len(keys)) % 16
    keys = np.concatenate([keys, np.full(pad, 10**15, dtype=u64)])
    found, items = cuckoo_lookup(tk, ti, keys, block_b=16)
    found = np.asarray(found)
    items = np.asarray(items)
    for i, k in enumerate(keys[: len(placed)]):
        assert found[i] == 1, f"placed key {k} not found"
        assert tuple(int(x) for x in items[i]) == placed[int(k)]
    assert (found[len(placed):] == 0).all()


def test_cuckoo_empty_table_all_miss():
    tk = np.full(256, ref.EMPTY, dtype=u64)
    ti = np.zeros((256, 4), dtype=u64)
    keys = np.arange(1, 33, dtype=u64)
    found, items = cuckoo_lookup(tk, ti, keys, block_b=16)
    assert (np.asarray(found) == 0).all()
    assert (np.asarray(items) == 0).all()


# ------------------------------------------------------------- predicate

@settings(max_examples=25, deadline=None)
@given(
    nbuckets_log=st.integers(min_value=4, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31),
    lsn_bias=st.integers(min_value=-5, max_value=5),
)
def test_predicate_matches_ref(nbuckets_log, seed, lsn_bias):
    rng = np.random.default_rng(seed)
    nbuckets = 1 << nbuckets_log
    tk, ti, placed = random_table(rng, nbuckets, nbuckets * 2)
    batch = 64
    present = np.array(list(placed.keys()) or [1], dtype=u64)
    keys = rng.choice(present, size=batch).astype(u64)
    cached_lsn = np.array([placed[int(k)][0] for k in keys], dtype=np.int64)
    lsns = np.maximum(cached_lsn + lsn_bias, 0).astype(u64)

    out_k = offload_predicate(tk, ti, keys, lsns, block_b=16)
    out_r = ref.predicate_ref(tk, ti, keys, lsns)
    for got, want in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(got), want)


def test_predicate_freshness_boundary():
    """offload iff cached_lsn >= requested lsn — check ±1 around it."""
    entries = [(42, (100, 7, 4096, 8192))]
    tk, ti, placed = ref.build_dense_table(entries, 16)
    assert placed
    keys = np.full(16, 42, dtype=u64)
    lsns = np.array([99, 100, 101] + [100] * 13, dtype=u64)
    mask, a, b, cd = (np.asarray(x) for x in offload_predicate(tk, ti, keys, lsns, block_b=16))
    assert mask[0] == 1 and mask[1] == 1 and mask[2] == 0
    assert a[0] == 100 and b[0] == 7
    assert cd[0, 0] == 4096 and cd[0, 1] == 8192
    # Masked rows are fully zeroed.
    assert a[2] == 0 and b[2] == 0 and cd[2].sum() == 0


def test_predicate_miss_goes_to_host():
    tk = np.full(64, ref.EMPTY, dtype=u64)
    ti = np.zeros((64, 4), dtype=u64)
    keys = np.arange(16, dtype=u64)
    lsns = np.zeros(16, dtype=u64)
    mask, *_ = offload_predicate(tk, ti, keys, lsns, block_b=16)
    assert (np.asarray(mask) == 0).all()


# -------------------------------------------------------------- checksum

@settings(max_examples=25, deadline=None)
@given(
    words_log=st.integers(min_value=2, max_value=11),
    batch=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_checksum_matches_ref(words_log, batch, seed):
    rng = np.random.default_rng(seed)
    w = 1 << words_log
    pages = rng.integers(0, 2**32, size=(batch, w), dtype=np.uint32)
    got = np.asarray(page_checksum(pages, block_b=4))
    want = ref.checksum_ref(pages)
    np.testing.assert_array_equal(got, want)


def test_checksum_zero_page_is_zero():
    pages = np.zeros((4, 64), dtype=np.uint32)
    assert (np.asarray(page_checksum(pages, block_b=4)) == 0).all()


def test_checksum_position_sensitive():
    a = np.zeros((4, 64), dtype=np.uint32)
    b = np.zeros((4, 64), dtype=np.uint32)
    a[0, 0] = 1
    b[0, 1] = 1
    ca = np.asarray(page_checksum(a, block_b=4))
    cb = np.asarray(page_checksum(b, block_b=4))
    assert ca[0] != cb[0]
    # s1 lane identical, s2 lane differs.
    assert ca[0] & 0xFFFFFFFF == cb[0] & 0xFFFFFFFF


def test_checksum_max_words_no_overflow():
    """Deferred-modulo trick must be exact at the AOT page size."""
    pages = np.full((4, 2048), 0xFFFFFFFF, dtype=np.uint32)
    got = np.asarray(page_checksum(pages, block_b=4))
    want = ref.checksum_ref(pages)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------ hash consistency

def test_hashes_match_rust_constants():
    """Spot-check h1/h2 against values computed from the rust formula
    (documented contract with rust/src/cache/table.rs)."""
    k = np.array([1, 42, 2**63 - 1], dtype=u64)
    nb = 2048
    exp_h1 = [(int(ki) * 0x9E3779B97F4A7C15 % 2**64) >> 17 & (nb - 1) for ki in k]
    x = [int(ki) ^ (int(ki) >> 33) for ki in k]
    exp_h2 = [(xi * 0xC2B2AE3D27D4EB4F % 2**64) >> 13 & (nb - 1) for xi in x]
    assert list(ref.h1(k, nb).astype(int)) == exp_h1
    assert list(ref.h2(k, nb).astype(int)) == exp_h2
