"""L2/AOT tests: model shapes, lowering to HLO text, and artifact
self-consistency (the text parses back into an XlaComputation and the
re-imported computation still computes the reference answer)."""

import os

import jax
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def test_predicate_model_shapes():
    args = model.predicate_example_args()
    out = jax.eval_shape(model.predicate_model, *args)
    assert [tuple(o.shape) for o in out] == [
        (model.PREDICATE_BATCH,),
        (model.PREDICATE_BATCH,),
        (model.PREDICATE_BATCH,),
        (model.PREDICATE_BATCH, 2),
    ]
    assert all(o.dtype == np.uint64 for o in out)


def test_checksum_model_shapes():
    args = model.checksum_example_args()
    (out,) = jax.eval_shape(model.checksum_model, *args)
    assert tuple(out.shape) == (model.CHECKSUM_BATCH,)
    assert out.dtype == np.uint64


def test_lowering_produces_parseable_hlo_text(tmp_path):
    paths = aot.build(str(tmp_path))
    assert len(paths) == len(aot.ARTIFACTS)
    for p in paths:
        text = open(p).read()
        assert "HloModule" in text
        # Round-trip through the HLO text parser (what the rust loader
        # does via HloModuleProto::from_text_file).
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None


def test_artifact_names_match_runtime_contract(tmp_path):
    """rust/src/runtime expects predicate.hlo.txt and checksum.hlo.txt."""
    paths = aot.build(str(tmp_path))
    names = sorted(os.path.basename(p) for p in paths)
    assert names == ["checksum.hlo.txt", "predicate.hlo.txt"]


def test_predicate_model_executes_like_ref():
    """Run the jitted L2 model (not just the kernel) against the oracle
    at the full AOT shape."""
    rng = np.random.default_rng(3)
    nbuckets = model.PREDICATE_SLOTS // ref.SLOTS
    entries = [
        (int(k), (int(rng.integers(1, 2**30)), 7, int(k) * 8192, 8192))
        for k in rng.choice(np.arange(1, 10**6, dtype=np.uint64), size=3000, replace=False)
    ]
    tk, ti, placed = ref.build_dense_table(entries, nbuckets)
    placed = dict(placed)
    keys = rng.choice(np.array(list(placed.keys()), dtype=np.uint64), size=model.PREDICATE_BATCH)
    lsns = np.array([max(placed[int(k)][0] - 1, 0) for k in keys], dtype=np.uint64)
    out = model.predicate_model(tk, ti, keys, lsns)
    want = ref.predicate_ref(tk, ti, keys, lsns)
    for got, w in zip(out, want):
        np.testing.assert_array_equal(np.asarray(got), w)
    # Every queried key was placed with fresh-enough LSN → all offload.
    assert np.asarray(out[0]).sum() == model.PREDICATE_BATCH
