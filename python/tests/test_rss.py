"""RSS Toeplitz steering: python batch implementation vs properties the
rust scalar implementation guarantees (same key, same normalization)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # skip, don't abort collection, when absent
from hypothesis import given, settings, strategies as st

from compile.kernels.rss import normalize_tuple, rss_core_batch, toeplitz_hash_batch


def test_deterministic_and_nontrivial():
    t = np.full((4, 12), 0x42, dtype=np.uint8)
    h1 = toeplitz_hash_batch(t)
    h2 = toeplitz_hash_batch(t)
    np.testing.assert_array_equal(h1, h2)
    t2 = t.copy()
    t2[0, 0] ^= 1
    assert toeplitz_hash_batch(t2)[0] != h1[0]
    assert (h1 == h1[0]).all()


@settings(max_examples=50, deadline=None)
@given(
    cip=st.integers(min_value=0, max_value=2**32 - 1),
    cport=st.integers(min_value=0, max_value=2**16 - 1),
    sip=st.integers(min_value=0, max_value=2**32 - 1),
    sport=st.integers(min_value=0, max_value=2**16 - 1),
    cores=st.sampled_from([1, 3, 8]),
)
def test_symmetric_steering(cip, cport, sip, sport, cores):
    fwd = (cip, cport, sip, sport)
    rev = (sip, sport, cip, cport)
    cores_out = rss_core_batch([fwd, rev], cores)
    assert cores_out[0] == cores_out[1]
    assert cores_out[0] < cores


def test_normalization_is_order_invariant():
    a = normalize_tuple(1, 2, 3, 4)
    b = normalize_tuple(3, 4, 1, 2)
    np.testing.assert_array_equal(a, b)


def test_spreads_over_cores():
    tuples = [(0x0A000000 + i, 1000 + 7 * i, 0x0A0000FF, 5000) for i in range(2000)]
    cores = rss_core_batch(tuples, 8)
    counts = np.bincount(cores.astype(int), minlength=8)
    assert (counts > 2000 / 8 / 3).all(), counts


def test_scalar_reference_agreement():
    """Bit-serial scalar Toeplitz (the rust algorithm, transcribed) must
    agree with the vectorized batch implementation."""
    from compile.kernels.rss import KEY

    def scalar(data: bytes) -> int:
        key_bits = np.unpackbits(np.frombuffer(KEY, dtype=np.uint8))
        result = 0
        window = int.from_bytes(KEY[:4].tobytes(), "big")
        next_bit = 32
        for byte in data:
            for bit in range(7, -1, -1):
                if byte >> bit & 1:
                    result ^= window
                kb = int(key_bits[next_bit]) if next_bit < len(key_bits) else 0
                window = ((window << 1) | kb) & 0xFFFFFFFF
                next_bit += 1
        return result

    rng = np.random.default_rng(5)
    batch = rng.integers(0, 256, size=(16, 12), dtype=np.uint8)
    got = toeplitz_hash_batch(batch)
    want = np.array([scalar(bytes(row.tolist())) for row in batch], dtype=np.uint32)
    np.testing.assert_array_equal(got, want)
