//! The RSS-sharded data plane (§7) end to end.
//!
//! Spawns a [`ShardedServer`] with 4 shards — each shard is an OS
//! thread running the whole DPU data path (per-flow split-TCP PEPs, its
//! own offload engine over its own SSD submission queue, and its own
//! host-app instance with a dedicated file-service poll group) — then
//! opens two client connections per shard, runs offloaded reads on all
//! of them concurrently, and prints per-shard statistics showing that
//! every flow stayed on the shard RSS assigned it.
//!
//! Run: `cargo run --release --offline --example sharded_server`

use std::sync::Arc;
use std::time::Duration;

use dds::apps::RawFileApp;
use dds::coordinator::{
    run_sharded_request, tuple_for_shard, ShardDriver, ShardedServer, ShardedServerConfig,
    StorageServer, StorageServerConfig,
};
use dds::director::AppSignature;
use dds::offload::RawFileOffload;
use dds::proto::{AppRequest, NetMsg};

const FILE_BYTES: u64 = 1 << 20;
const SHARDS: usize = 4;

fn main() -> anyhow::Result<()> {
    // One storage path (SSD + DPU file system + file service), shared.
    let logic = Arc::new(RawFileOffload);
    let storage = StorageServer::build(StorageServerConfig::default(), Some(logic.clone()))?;

    // Create and fill the data file before the shards spawn.
    let file = storage.create_filled_file("demo", "data", FILE_BYTES)?;
    let fid = file.id.0;

    // N shards over the storage path; each shard's host app gets its
    // own poll group — the single file service drains all of them.
    let cfg = ShardedServerConfig { shards: SHARDS, ..Default::default() };
    let server = ShardedServer::over(
        storage,
        cfg,
        logic,
        AppSignature::server_port(5000),
        |_shard, st| RawFileApp::over(st, &file),
    )?;

    // One driver thread per shard, two connections each.
    let total: u64 = std::thread::scope(|scope| -> anyhow::Result<u64> {
        let mut handles = Vec::new();
        for s in 0..SHARDS {
            let server = &server;
            handles.push(scope.spawn(move || -> anyhow::Result<u64> {
                let mut driver = ShardDriver::new(s);
                let tuples: Vec<_> = (0..2u16)
                    .map(|c| {
                        tuple_for_shard(
                            s,
                            SHARDS,
                            0x0a00_0001 + c as u32,
                            43_000 + s as u16 * 53 + c,
                            0x0a00_00ff,
                            5000,
                        )
                    })
                    .collect();
                for &t in &tuples {
                    driver.connect(server, t)?;
                }
                let mut ops = 0u64;
                for round in 0..20u64 {
                    for (c, t) in tuples.iter().enumerate() {
                        let base =
                            ((s as u64 * 131 + c as u64 * 17 + round) * 512) % (FILE_BYTES - 2048);
                        let msg = NetMsg {
                            msg_id: (s as u64) << 32 | (c as u64) << 16 | round,
                            requests: (0..4u64)
                                .map(|j| AppRequest::Read {
                                    file_id: fid,
                                    offset: base + j * 512,
                                    size: 512,
                                })
                                .collect(),
                        };
                        let resps = run_sharded_request(
                            server,
                            &mut driver,
                            t,
                            &msg,
                            Duration::from_secs(10),
                        )?;
                        for r in &resps {
                            anyhow::ensure!(r.status == 0, "read failed");
                        }
                        ops += resps.len() as u64;
                    }
                }
                Ok(ops)
            }));
        }
        let mut total = 0;
        for h in handles {
            total += h.join().expect("driver panicked")?;
        }
        Ok(total)
    })?;

    println!("{total} offloaded reads served across {SHARDS} shards\n");
    println!("per-shard stats (no flow ever crossed a shard):");
    for st in server.shard_stats() {
        println!(
            "  shard {}: flows={} msgs={} offloaded={} to_host={}",
            st.shard, st.flows, st.msgs_in, st.reqs_offloaded, st.reqs_to_host
        );
    }
    let agg = server.stats();
    anyhow::ensure!(agg.flows == (SHARDS * 2) as u64, "every connection stayed shard-local");
    println!("\nsharded server OK");
    Ok(())
}
