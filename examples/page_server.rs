//! End-to-end driver: an Azure-SQL-Hyperscale-style page server on DDS
//! (§9.1) — the full three-layer system on a real small workload.
//!
//! Pipeline exercised, all functional (real bytes, no simulation):
//!   client (TCP segments) → DPU traffic director (PEP split, OffPred
//!   against the cuckoo cache table) → offload engine (context ring,
//!   mem-pool, zero-copy) → DPU file system → in-memory NVMe — and the
//!   host path for stale-LSN pages: director → host connection → page
//!   server app → DDS file library → DMA rings → DPU file service.
//!
//! The run: create a page-server with a real page file, replay log
//! records (which exercises invalidate-on-read + cache-on-write), then
//! serve batched GetPage@LSN requests and report throughput, latency,
//! offload ratio, and correctness of every returned page.
//!
//! Run: `cargo run --release --offline --example page_server [pages] [requests]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use dds::apps::{PageServer, PageServerOffload, PAGE_SIZE};
use dds::coordinator::{run_request, ClientConn, DisaggregatedServer, StorageServer, StorageServerConfig};
use dds::director::AppSignature;
use dds::metrics::{fmt_ns, fmt_ops, Histogram};
use dds::net::FiveTuple;
use dds::offload::OffloadEngineConfig;
use dds::sim::Rng;
use dds::workload::GetPageGen;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_pages: u64 = args.first().map_or(512, |v| v.parse().unwrap_or(512));
    let n_requests: usize = args.get(1).map_or(4000, |v| v.parse().unwrap_or(4000));

    println!("== DDS page server: {n_pages} pages × {PAGE_SIZE} B, {n_requests} GetPage@LSN ==");

    // --- build the server -----------------------------------------------
    // File ids are allocated deterministically; the RBPEX file is the
    // first file created, so the offload logic can be installed at
    // storage-server build time (it must see the initial page fill via
    // cache-on-write).
    let rbpex_file = dds::dpufs::FileId(1);
    let logic = Arc::new(PageServerOffload { rbpex_file });
    let storage = StorageServer::build(StorageServerConfig::default(), Some(logic.clone()))?;
    let fe = storage.front_end();
    let dir = fe.create_directory("db").map_err(|e| anyhow::anyhow!("{e}"))?;
    let file = fe.create_file(dir, "rbpex").map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(file.id == rbpex_file, "unexpected file id");

    let t0 = Instant::now();
    let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut app = PageServer::new(fe, file, group, n_pages)?;
    println!("initialized {} pages in {:.2?}", n_pages, t0.elapsed());

    // --- replay some log records (host write path, §9.1) -----------------
    let mut rng = Rng::new(7);
    let mut latest_lsn = 1u64;
    for i in 0..n_pages / 4 {
        latest_lsn = 2 + i;
        let page = rng.next_range(n_pages);
        app.replay_log(page, latest_lsn)?;
    }
    println!("replayed {} log records (max LSN {latest_lsn})", app.logs_replayed);
    let cached = storage.cache.len();
    println!("cache table: {cached} pages cached on the DPU");

    let mut server = DisaggregatedServer::new(
        storage,
        logic,
        AppSignature::server_port(1433),
        OffloadEngineConfig { pool_buf_size: PAGE_SIZE + 64, ..Default::default() },
        app,
    );

    // --- drive the workload ----------------------------------------------
    let tuple = FiveTuple::new(0x0a00_0002, 50001, 0x0a00_00fe, 1433);
    let mut client = ClientConn::new(tuple);
    let mut gen = GetPageGen::new(n_pages, 8, 99);
    gen.current_lsn = 1; // request LSN ≤ every page's applied LSN

    let mut hist = Histogram::new();
    let mut served = 0usize;
    let mut bad = 0usize;
    let t0 = Instant::now();
    while served < n_requests {
        let msg = gen.next_msg();
        let sent = Instant::now();
        let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(10))?;
        hist.record(sent.elapsed().as_nanos() as u64);
        for (resp, req) in resps.iter().zip(&msg.requests) {
            served += 1;
            // Validate the page: header must carry the requested id.
            let dds::proto::AppRequest::GetPage { page_id, .. } = req else { unreachable!() };
            if resp.status != 0
                || resp.payload.len() != PAGE_SIZE
                || u64::from_le_bytes(resp.payload[..8].try_into().unwrap()) != *page_id
            {
                bad += 1;
            }
        }
    }
    let dt = t0.elapsed();

    // --- report -----------------------------------------------------------
    let tput = served as f64 / dt.as_secs_f64();
    println!("\nserved {served} pages in {dt:.2?}");
    println!("  throughput      : {} pages/s ({} MB/s)", fmt_ops(tput), (tput * PAGE_SIZE as f64 / 1e6) as u64);
    println!(
        "  batch latency   : p50 {}  p99 {}",
        fmt_ns(hist.p50()),
        fmt_ns(hist.p99())
    );
    println!(
        "  offloaded       : {} requests ({}%)",
        server.director.reqs_offloaded,
        100 * server.director.reqs_offloaded / (server.director.reqs_offloaded + server.director.reqs_to_host).max(1)
    );
    println!("  host-served     : {}", server.director.reqs_to_host);
    println!("  bad pages       : {bad}");
    anyhow::ensure!(bad == 0, "payload validation failed");
    anyhow::ensure!(
        server.director.reqs_offloaded > 0,
        "nothing offloaded — cache-on-write broken?"
    );
    println!("page_server OK");
    Ok(())
}
