//! Sustained log replay concurrent with page serving (§9.1 dynamics).
//!
//! The Hyperscale page server continuously replays log records shipped
//! from the log server while compute nodes read pages. This example
//! runs both against the full functional stack and checks the
//! freshness interplay the DDS design hinges on:
//!
//! * a replay *invalidates* the page on the DPU (host read) and then
//!   *re-caches* it at the new LSN (write-back) — so requests at old
//!   LSNs keep offloading, while a request racing ahead of replay
//!   bounces to the host and is refused until the LSN is applied;
//! * every served page carries an LSN ≥ the requested LSN.
//!
//! Run: `cargo run --release --offline --example log_replay [pages] [rounds]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use dds::apps::{PageServer, PageServerOffload, PAGE_SIZE};
use dds::coordinator::{run_request, ClientConn, DisaggregatedServer, StorageServer, StorageServerConfig};
use dds::director::AppSignature;
use dds::net::FiveTuple;
use dds::offload::OffloadEngineConfig;
use dds::proto::{AppRequest, NetMsg};
use dds::sim::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_pages: u64 = args.first().map_or(128, |v| v.parse().unwrap_or(128));
    let rounds: u64 = args.get(1).map_or(40, |v| v.parse().unwrap_or(40));

    let rbpex_file = dds::dpufs::FileId(1);
    let logic = Arc::new(PageServerOffload { rbpex_file });
    let storage = StorageServer::build(StorageServerConfig::default(), Some(logic.clone()))?;
    let fe = storage.front_end();
    let dir = fe.create_directory("db").map_err(|e| anyhow::anyhow!("{e}"))?;
    let file = fe.create_file(dir, "rbpex").map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(file.id == rbpex_file);
    let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
    let app = PageServer::new(fe, file, group, n_pages)?;
    let mut server = DisaggregatedServer::new(
        storage,
        logic,
        AppSignature::server_port(1433),
        OffloadEngineConfig { pool_buf_size: PAGE_SIZE + 64, ..Default::default() },
        app,
    );

    let tuple = FiveTuple::new(0x0a00_0009, 51000, 0x0a00_00f0, 1433);
    let mut client = ClientConn::new(tuple);
    let mut rng = Rng::new(2024);

    // Per-page applied LSN, mirrored from the replay stream (GetPage@LSN
    // is satisfiable only once the page's own log has been applied).
    let mut page_lsn: Vec<u64> = vec![1; n_pages as usize];
    let mut applied_lsn = 1u64;
    let mut served = 0u64;
    let mut refused_ahead = 0u64;
    let t0 = Instant::now();

    for round in 0..rounds {
        // --- replay a burst of log records (log server ships a batch) ---
        let burst = 1 + rng.next_range(8);
        for _ in 0..burst {
            applied_lsn += 1;
            let page = rng.next_range(n_pages);
            server.app.replay_log(page, applied_lsn)?;
            page_lsn[page as usize] = applied_lsn;
        }

        // --- serve a batch of reads at mixed LSNs ----------------------
        let mut requests = Vec::new();
        for i in 0..8u64 {
            let page_id = rng.next_range(n_pages);
            let cur = page_lsn[page_id as usize];
            // Mostly at-or-behind the page's applied LSN; the last
            // request races ahead of replay.
            let lsn = if i == 7 { cur + 5 } else { 1 + rng.next_range(cur) };
            requests.push(AppRequest::GetPage { page_id, lsn });
        }
        let msg = NetMsg { msg_id: round + 1, requests: requests.clone() };
        let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(10))?;
        for (resp, req) in resps.iter().zip(&requests) {
            let AppRequest::GetPage { page_id, lsn } = req else { unreachable!() };
            let cur = page_lsn[*page_id as usize];
            if *lsn > cur {
                // Raced ahead of replay: must be refused (status != 0),
                // never served stale.
                anyhow::ensure!(resp.status != 0, "page served ahead of its LSN!");
                refused_ahead += 1;
                continue;
            }
            anyhow::ensure!(resp.status == 0, "valid request failed");
            anyhow::ensure!(resp.payload.len() == PAGE_SIZE);
            let got_id = u64::from_le_bytes(resp.payload[..8].try_into().unwrap());
            let got_lsn = u64::from_le_bytes(resp.payload[8..16].try_into().unwrap());
            anyhow::ensure!(got_id == *page_id, "wrong page");
            anyhow::ensure!(got_lsn >= *lsn, "stale page: lsn {got_lsn} < requested {lsn}");
            served += 1;
        }
    }

    let (offloaded, to_host) =
        (server.director.reqs_offloaded, server.director.reqs_to_host);
    println!("log_replay: {rounds} rounds in {:.2?}", t0.elapsed());
    println!("  applied LSN     : {applied_lsn} ({} replays)", server.app.logs_replayed);
    println!("  pages served    : {served} (all fresh, LSN-checked)");
    println!("  refused (ahead) : {refused_ahead}");
    println!("  offloaded/host  : {offloaded} / {to_host}");
    anyhow::ensure!(offloaded > 0 && to_host > 0, "expected a mix of DPU and host service");
    println!("log_replay OK");
    Ok(())
}
