//! §Perf probe: wallclock micro-measurements of the L3 hot paths the
//! optimization pass tracks (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo run --release --offline --example perf_probe`

use std::time::Duration;

use dds::dma::DmaChannel;
use dds::metrics::bench::{black_box, time_for};
use dds::metrics::fmt_ops;
use dds::proto::{FileRequest, FileResponse, Status};
use dds::ring::{ProgressRing, RequestRing, ResponseRing, RingStatus};

fn ring_roundtrip(msg_len: usize, batch: usize) -> f64 {
    let ring = ProgressRing::new(1 << 22, 1 << 20);
    let dma = DmaChannel::new();
    let msg = vec![0xabu8; msg_len];
    let mut sink = 0u64;
    let r = time_for(Duration::from_millis(600), |_| {
        for _ in 0..batch {
            assert_eq!(ring.try_push(&msg), RingStatus::Ok);
        }
        let n = ring.pop_batch_dma(&dma, &mut |m| sink += m[0] as u64);
        assert_eq!(n, batch);
    });
    black_box(sink);
    r.ops_per_sec() * batch as f64
}

fn resp_ring_roundtrip(msg_len: usize) -> f64 {
    let ring = ResponseRing::new(1 << 22);
    let dma = DmaChannel::new();
    let msg = vec![0xcdu8; msg_len];
    let mut sink = 0u64;
    let r = time_for(Duration::from_millis(600), |_| {
        assert_eq!(ring.push_dma(&dma, &msg), RingStatus::Ok);
        ring.pop(&mut |m| sink += m[0] as u64);
    });
    black_box(sink);
    r.ops_per_sec()
}

fn proto_roundtrip() -> f64 {
    let payload = vec![7u8; 1024];
    let r = time_for(Duration::from_millis(400), |i| {
        let req = FileRequest::write(i, 1, 0, payload.clone());
        let enc = req.encode();
        black_box(FileRequest::decode(&enc).unwrap());
        let resp = FileResponse { req_id: i, status: Status::Ok, data: payload.clone() };
        black_box(FileResponse::decode(&resp.encode()).unwrap());
    });
    r.ops_per_sec()
}

fn main() {
    println!("== L3 hot-path probe (single core) ==");
    for (label, len, batch) in
        [("8 B msgs, batch 32", 8, 32), ("1 KB msgs, batch 8", 1024, 8), ("8 KB msgs, batch 8", 8192, 8)]
    {
        println!("req ring  {label:>20}: {} msgs/s", fmt_ops(ring_roundtrip(len, batch)));
    }
    for (label, len) in [("64 B", 64), ("1 KB", 1024), ("8 KB", 8192)] {
        println!("resp ring {label:>20}: {} msgs/s", fmt_ops(resp_ring_roundtrip(len)));
    }
    println!("proto enc/dec (1 KB w+r)  : {} pairs/s", fmt_ops(proto_roundtrip()));
}
