//! Disaggregated FASTER-style KV service on DDS (§9.2).
//!
//! Loads a mini hybrid-log KV whose storage-resident records live on
//! the DPU file system behind an IDevice built on the DDS front-end
//! library; flushes populate the DPU cache table via cache-on-write;
//! remote `KvGet`s of flushed records execute entirely on the DPU while
//! in-memory (tail) records bounce to the host; RMWs pull records back
//! and invalidate their DPU cache entries — stale reads are checked for
//! explicitly.
//!
//! Run: `cargo run --release --offline --example kv_service [keys] [gets]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use dds::apps::{FasterOffload, MiniFaster};
use dds::coordinator::{run_request, ClientConn, DisaggregatedServer, StorageServer, StorageServerConfig};
use dds::director::AppSignature;
use dds::metrics::{fmt_ns, fmt_ops, Histogram};
use dds::net::FiveTuple;
use dds::offload::OffloadEngineConfig;
use dds::proto::AppRequest;
use dds::workload::YcsbGen;

fn value_for(key: u64, version: u64) -> Vec<u8> {
    let mut v = vec![0u8; 16];
    v[..8].copy_from_slice(&key.to_le_bytes());
    v[8..].copy_from_slice(&version.to_le_bytes());
    v
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_keys: u64 = args.first().map_or(2000, |v| v.parse().unwrap_or(2000));
    let n_gets: usize = args.get(1).map_or(4000, |v| v.parse().unwrap_or(4000));

    println!("== DDS KV service: {n_keys} keys, {n_gets} YCSB uniform GETs ==");

    let idevice_file = dds::dpufs::FileId(1);
    let logic = Arc::new(FasterOffload { idevice_file });
    let storage = StorageServer::build(StorageServerConfig::default(), Some(logic.clone()))?;
    let fe = storage.front_end();
    let dir = fe.create_directory("kv").map_err(|e| anyhow::anyhow!("{e}"))?;
    let file = fe.create_file(dir, "idevice").map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(file.id == idevice_file, "unexpected file id");
    let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;

    // Small memory budget forces storage residency (§9.2: "stores most
    // records in storage").
    let mut kv = MiniFaster::new(fe, file, group, 16 << 10).with_cache(storage.cache.clone());
    let t0 = Instant::now();
    for key in 0..n_keys {
        kv.upsert(key, value_for(key, 1))?;
    }
    kv.flush()?; // everything storage-resident + DPU-cached
    println!(
        "loaded {n_keys} keys in {:.2?} ({} flushes); cache table: {} entries",
        t0.elapsed(),
        kv.flushes,
        storage.cache.len()
    );

    // RMW a slice of keys: their cache entries must be invalidated and
    // subsequent remote reads must see the NEW value via the host.
    let rmw_keys: Vec<u64> = (0..n_keys).step_by(17).collect();
    for &k in &rmw_keys {
        kv.rmw(k, |v| {
            let ver = u64::from_le_bytes(v[8..16].try_into().unwrap());
            v[8..16].copy_from_slice(&(ver + 1).to_le_bytes());
        })?;
    }
    println!("RMW'd {} keys (DPU entries invalidated)", rmw_keys.len());

    let mut server = DisaggregatedServer::new(
        storage,
        logic,
        AppSignature::server_port(6379),
        OffloadEngineConfig::default(),
        kv,
    );

    let tuple = FiveTuple::new(0x0a00_0003, 50002, 0x0a00_00fd, 6379);
    let mut client = ClientConn::new(tuple);
    let mut gen = YcsbGen::uniform(n_keys, 1.0, 16, 8, 5);

    let mut hist = Histogram::new();
    let mut served = 0usize;
    let mut bad = 0usize;
    let t0 = Instant::now();
    while served < n_gets {
        let msg = gen.next_msg();
        let sent = Instant::now();
        let resps = run_request(&mut client, &mut server, &msg, Duration::from_secs(10))?;
        hist.record(sent.elapsed().as_nanos() as u64);
        for (resp, req) in resps.iter().zip(&msg.requests) {
            served += 1;
            let AppRequest::KvGet { key } = req else { unreachable!() };
            let rmwed = key % 17 == 0;
            let expect = value_for(*key, if rmwed { 2 } else { 1 });
            // The record header precedes the value on the DPU path;
            // host path returns the bare value.
            let got_value = if resp.payload.len() == expect.len() + dds::apps::faster::REC_HEADER
            {
                &resp.payload[dds::apps::faster::REC_HEADER..]
            } else {
                &resp.payload[..]
            };
            if resp.status != 0 || got_value != expect {
                bad += 1;
            }
        }
    }
    let dt = t0.elapsed();

    let tput = served as f64 / dt.as_secs_f64();
    println!("\nserved {served} GETs in {dt:.2?}");
    println!("  throughput : {} op/s", fmt_ops(tput));
    println!("  batch p50  : {}   p99 {}", fmt_ns(hist.p50()), fmt_ns(hist.p99()));
    println!(
        "  offloaded  : {} ({:.0}%)  host: {}",
        server.director.reqs_offloaded,
        100.0 * server.director.reqs_offloaded as f64
            / (server.director.reqs_offloaded + server.director.reqs_to_host).max(1) as f64,
        server.director.reqs_to_host
    );
    println!("  stale/bad  : {bad}");
    anyhow::ensure!(bad == 0, "stale or corrupt reads detected");
    anyhow::ensure!(server.director.reqs_offloaded > 0, "no DPU offloading happened");
    println!("kv_service OK");
    Ok(())
}
