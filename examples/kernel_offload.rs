//! Three-layer composition: the AOT Pallas predicate kernel on the
//! DPU data path.
//!
//! Builds a real cuckoo cache table (L3), exports its dense slot
//! arrays, and evaluates GetPage@LSN offload predicates for a batch of
//! requests with the AOT-compiled Pallas kernel via PJRT (L1/L2),
//! verifying every decision against the scalar rust path — then runs
//! the checksum kernel over the pages an offloaded batch would serve.
//!
//! Requires `make artifacts` first.
//!
//! Run: `cargo run --release --offline --example kernel_offload`

use dds::cache::{CacheItem, CuckooCache};
use dds::metrics::bench::{time_for, black_box};
use dds::metrics::fmt_ops;
use dds::runtime::{checksum_ref, KernelRuntime, CHECKSUM_BATCH, CHECKSUM_PAGE, PREDICATE_BATCH, PREDICATE_SLOTS};
use dds::sim::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = KernelRuntime::artifacts_dir();
    let mut rt = KernelRuntime::cpu()?;
    let loaded = rt.load_dir(&dir)?;
    println!("loaded kernels: {loaded:?}");

    // --- build a real cache table sized for the kernel's AOT shape ----
    // PREDICATE_SLOTS slots = buckets*4; CuckooCache::new sizes buckets
    // = next_pow2(2*capacity/4), so capacity = SLOTS/2 gives exactly
    // PREDICATE_SLOTS slots.
    let cache = CuckooCache::new(PREDICATE_SLOTS / 2);
    let mut rng = Rng::new(42);
    let mut inserted = Vec::new();
    for _ in 0..PREDICATE_SLOTS / 4 {
        let page_id = rng.next_range(1 << 40) + 1;
        let lsn = rng.next_range(1000) + 1;
        let item = CacheItem::new(lsn, 1, page_id * 8192, 8192);
        if cache.insert(page_id, item) {
            inserted.push((page_id, lsn));
        }
    }
    let dense = cache.export_dense();
    anyhow::ensure!(dense.keys.len() == PREDICATE_SLOTS);
    println!("cache table: {} entries exported to dense arrays", cache.len());

    // --- batch of GetPage@LSN requests --------------------------------
    let mut keys = Vec::with_capacity(PREDICATE_BATCH);
    let mut lsns = Vec::with_capacity(PREDICATE_BATCH);
    for i in 0..PREDICATE_BATCH {
        if i % 3 == 0 {
            // Unknown page → host.
            keys.push(rng.next_range(1 << 40) + (1 << 50));
            lsns.push(0);
        } else {
            let (page, lsn) = inserted[rng.next_range(inserted.len() as u64) as usize];
            keys.push(page);
            // Mix of fresh-enough and too-new requests.
            lsns.push(if i % 3 == 1 { lsn } else { lsn + 1 });
        }
    }

    // --- kernel vs scalar rust ----------------------------------------
    let hits = rt.predicate_batch(&dense, &keys, &lsns)?;
    let mut offloaded = 0;
    for (i, hit) in hits.iter().enumerate() {
        let scalar = match cache.get(keys[i]) {
            Some(item) if item.a >= lsns[i] => Some(item),
            _ => None,
        };
        match (hit.offload, scalar) {
            (true, Some(item)) => {
                anyhow::ensure!(
                    (hit.a, hit.b, hit.c, hit.d) == (item.a, item.b, item.c, item.d),
                    "item mismatch at {i}"
                );
                offloaded += 1;
            }
            (false, None) => {}
            // Chained entries are not exported; kernel says host,
            // scalar says offload — allowed (documented fallback).
            (false, Some(_)) => {}
            (true, None) => anyhow::bail!("kernel offloads a request rust would not ({i})"),
        }
    }
    println!(
        "predicate kernel: {offloaded}/{PREDICATE_BATCH} offloadable, all decisions sound"
    );

    // --- throughput of the batched predicate path ----------------------
    let r = time_for(Duration::from_secs(1), |_| {
        black_box(rt.predicate_batch(&dense, &keys, &lsns).unwrap());
    });
    println!(
        "predicate batches: {:.0}/s → {} predicate evaluations/s (B={PREDICATE_BATCH})",
        r.ops_per_sec(),
        fmt_ops(r.ops_per_sec() * PREDICATE_BATCH as f64),
    );

    // --- checksum the pages an offloaded batch would serve -------------
    let pages: Vec<u8> =
        (0..CHECKSUM_BATCH * CHECKSUM_PAGE).map(|i| (i % 251) as u8).collect();
    let sums = rt.checksum_batch(&pages)?;
    for (i, page) in pages.chunks(CHECKSUM_PAGE).enumerate() {
        anyhow::ensure!(sums[i] == checksum_ref(page), "checksum mismatch {i}");
    }
    println!("checksum kernel: {} pages verified against rust reference", sums.len());
    println!("kernel_offload OK");
    Ok(())
}
