//! Quickstart: the DDS unified storage path in 60 lines.
//!
//! Builds a storage server (in-memory NVMe + DPU file system + file
//! service thread), then uses the host front-end library exactly as a
//! storage application would (§4.2): create a directory and file, write
//! with `WriteFile`/gathered writes, read back with `ReadFile` and a
//! scattered read, and poll completions in both non-blocking and
//! sleeping modes.
//!
//! Run: `cargo run --release --offline --example quickstart`

use std::time::Duration;

use dds::coordinator::{StorageServer, StorageServerConfig};

fn main() -> anyhow::Result<()> {
    // The DPU side: SSD, file system, cache table, file service thread.
    let storage = StorageServer::build(StorageServerConfig::default(), None)?;

    // The host side: the DDS front-end library (§4.2).
    let fe = storage.front_end();
    let dir = fe.create_directory("demo").map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut file = fe.create_file(dir, "hello.dat").map_err(|e| anyhow::anyhow!("{e}"))?;

    // A notification group allocates DMA-registered request/response
    // rings (CreatePoll + PollAdd).
    let group = fe.create_poll().map_err(|e| anyhow::anyhow!("{e}"))?;
    fe.poll_add(&mut file, &group);

    // --- writes ---------------------------------------------------------
    let part1: &[u8] = b"hello, disaggregated ";
    let part2: &[&[u8]] = &[b"storage", b" ", b"world!"];
    let part2_len: usize = part2.iter().map(|b| b.len()).sum();
    let total = part1.len() + part2_len;

    let w1 = fe.write_file(&file, 0, part1).map_err(|e| anyhow::anyhow!("{e}"))?;
    // Gathered write: several source buffers, one file I/O (§4.2).
    let w2 = fe
        .gather_write(&file, part1.len() as u64, part2)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Sleeping-mode PollWait: zero CPU until the DPU doorbell fires.
    let mut done = Vec::new();
    while done.len() < 2 {
        for ev in group.poll_wait(Duration::from_secs(1)) {
            assert!(ev.ok, "write failed");
            done.push(ev.req_id);
        }
    }
    assert!(done.contains(&w1) && done.contains(&w2));
    let size = fe.file_size(&file).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("writes complete: file is {size} bytes");

    // --- reads ----------------------------------------------------------
    let r = fe.read_file(&file, 0, total as u32).map_err(|e| anyhow::anyhow!("{e}"))?;
    // Scattered read: one I/O split back into caller buffers.
    let sizes = [part1.len() as u32, 7, (total - part1.len() - 7) as u32];
    let s = fe.scatter_read(&file, 0, &sizes).map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut got = 0;
    while got < 2 {
        // Non-blocking-ish poll loop.
        for ev in group.poll_wait(Duration::from_millis(50)) {
            if ev.req_id == r {
                let text = String::from_utf8_lossy(&ev.data).into_owned();
                println!("ReadFile    → {text:?}");
                assert_eq!(text, "hello, disaggregated storage world!");
            } else if ev.req_id == s {
                let parts = ev.scatter();
                println!(
                    "ScatterRead → {:?} | {:?} | {:?}",
                    String::from_utf8_lossy(parts[0]),
                    String::from_utf8_lossy(parts[1]),
                    String::from_utf8_lossy(parts[2]),
                );
                assert_eq!(parts[0], part1);
            } else {
                continue;
            }
            got += 1;
        }
    }

    // Persist DPU file-system metadata (segment 0, §4.3).
    fe.sync_metadata().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("quickstart OK");
    Ok(())
}
